//! CI bench-regression gate.
//!
//! Compares freshly emitted `BENCH_*.json` files against the committed
//! baseline `ci/bench_baseline.json` and exits non-zero when any tracked
//! metric regresses by more than the tolerance. A missing bench file or a
//! missing metric path is a **failure**, not a skip — a silently absent
//! bench artifact must never pass the gate.
//!
//! The baseline deliberately tracks **machine-normalized ratios** (e.g.
//! incremental-vs-full speedup, sharded-vs-serial speedup, cluster-K1 vs
//! single events/sec) rather than absolute microseconds: CI runners vary
//! wildly across generations, but a ratio of two measurements taken on the
//! same box in the same job is comparable across runners. Baseline values
//! are conservative floors; tighten them as the trajectory accumulates.
//!
//! Baseline format (parsed with the in-crate JSON reader — no serde):
//!
//! ```json
//! {
//!   "tolerance": 0.25,
//!   "metrics": [
//!     {"name": "...", "file": "BENCH_hotpath.json",
//!      "path": "configs.0.order_alloc_speedup",
//!      "better": "higher", "value": 1.0, "tolerance": 0.25}
//!   ]
//! }
//! ```
//!
//! `path` is a dot-separated walk; numeric segments index arrays. The
//! per-metric `tolerance` (optional) overrides the file-level one.
//!
//! ```text
//! bench_gate --baseline ../ci/bench_baseline.json --dir ..
//! ```

use philae::util::json::JsonValue;
use std::path::{Path, PathBuf};

/// Walk a dot-separated path (`configs.0.speedup`) through a JSON value.
fn lookup<'a>(root: &'a JsonValue, path: &str) -> Option<&'a JsonValue> {
    let mut cur = root;
    for seg in path.split('.') {
        cur = match cur {
            JsonValue::Array(items) => items.get(seg.parse::<usize>().ok()?)?,
            JsonValue::Object(_) => cur.get(seg)?,
            _ => return None,
        };
    }
    Some(cur)
}

/// One tracked metric from the baseline file.
#[derive(Debug, Clone)]
struct Metric {
    name: String,
    file: String,
    path: String,
    higher_is_better: bool,
    value: f64,
    tolerance: f64,
}

/// A metric's verdict: `Ok(fresh_value)` or an explanation.
fn check(metric: &Metric, fresh: f64) -> Result<(), String> {
    if metric.higher_is_better {
        let floor = metric.value * (1.0 - metric.tolerance);
        if fresh < floor {
            return Err(format!(
                "{} regressed: {fresh:.4} < floor {floor:.4} (baseline {:.4}, tolerance {:.0}%)",
                metric.name,
                metric.value,
                metric.tolerance * 100.0
            ));
        }
    } else {
        let ceil = metric.value * (1.0 + metric.tolerance);
        if fresh > ceil {
            return Err(format!(
                "{} regressed: {fresh:.4} > ceiling {ceil:.4} (baseline {:.4}, tolerance {:.0}%)",
                metric.name,
                metric.value,
                metric.tolerance * 100.0
            ));
        }
    }
    Ok(())
}

fn parse_baseline(doc: &JsonValue) -> Result<Vec<Metric>, String> {
    let default_tol = doc
        .get("tolerance")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.25);
    let JsonValue::Array(items) = doc
        .get("metrics")
        .ok_or("baseline has no \"metrics\" array")?
    else {
        return Err("\"metrics\" is not an array".into());
    };
    let mut out = Vec::new();
    for (i, m) in items.iter().enumerate() {
        let get_str = |key: &str| -> Result<String, String> {
            m.get(key)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| format!("metric {i}: missing string field {key:?}"))
        };
        let better = get_str("better")?;
        if better != "higher" && better != "lower" {
            return Err(format!("metric {i}: \"better\" must be higher|lower, got {better:?}"));
        }
        out.push(Metric {
            name: get_str("name")?,
            file: get_str("file")?,
            path: get_str("path")?,
            higher_is_better: better == "higher",
            value: m
                .get("value")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("metric {i}: missing numeric \"value\""))?,
            tolerance: m
                .get("tolerance")
                .and_then(|v| v.as_f64())
                .unwrap_or(default_tol),
        });
    }
    Ok(out)
}

fn run(baseline_path: &Path, dir: &Path) -> Result<usize, String> {
    let text = std::fs::read_to_string(baseline_path)
        .map_err(|e| format!("cannot read baseline {}: {e}", baseline_path.display()))?;
    let doc = JsonValue::parse(&text).map_err(|e| format!("baseline parse error: {e}"))?;
    let metrics = parse_baseline(&doc)?;
    if metrics.is_empty() {
        return Err("baseline tracks no metrics — the gate would be vacuous".into());
    }

    // parse each referenced bench file once
    let mut docs: Vec<(String, JsonValue)> = Vec::new();
    for m in &metrics {
        if docs.iter().any(|(f, _)| f == &m.file) {
            continue;
        }
        let p = dir.join(&m.file);
        let text = std::fs::read_to_string(&p)
            .map_err(|e| format!("bench artifact {} missing or unreadable: {e}", p.display()))?;
        let v = JsonValue::parse(&text).map_err(|e| format!("{}: parse error: {e}", m.file))?;
        docs.push((m.file.clone(), v));
    }

    let mut failures: Vec<String> = Vec::new();
    println!("bench gate: {} tracked metrics", metrics.len());
    for m in &metrics {
        let doc = &docs.iter().find(|(f, _)| f == &m.file).unwrap().1;
        match lookup(doc, &m.path).and_then(|v| v.as_f64()) {
            None => failures.push(format!(
                "{}: path {:?} not found (or not a number) in {}",
                m.name, m.path, m.file
            )),
            Some(fresh) => {
                let verdict = check(m, fresh);
                let mark = if verdict.is_ok() { "ok  " } else { "FAIL" };
                println!(
                    "  [{mark}] {:<46} fresh {:>10.4} | baseline {:>10.4} ({})",
                    m.name,
                    fresh,
                    m.value,
                    if m.higher_is_better { "higher is better" } else { "lower is better" }
                );
                if let Err(e) = verdict {
                    failures.push(e);
                }
            }
        }
    }
    if failures.is_empty() {
        Ok(metrics.len())
    } else {
        Err(failures.join("\n"))
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut baseline = PathBuf::from("ci/bench_baseline.json");
    let mut dir = PathBuf::from(".");
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--baseline" if i + 1 < args.len() => {
                baseline = PathBuf::from(&args[i + 1]);
                i += 2;
            }
            "--dir" if i + 1 < args.len() => {
                dir = PathBuf::from(&args[i + 1]);
                i += 2;
            }
            other => {
                eprintln!("usage: bench_gate [--baseline <json>] [--dir <bench-artifact-dir>]");
                eprintln!("unexpected argument {other:?}");
                std::process::exit(2);
            }
        }
    }
    match run(&baseline, &dir) {
        Ok(n) => println!("bench gate passed ({n} metrics within tolerance)"),
        Err(e) => {
            eprintln!("bench gate FAILED:\n{e}");
            std::process::exit(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_walks_objects_and_arrays() {
        let doc = JsonValue::parse(
            r#"{"configs": [{"speedup": 2.5}, {"nested": {"x": [1, 2, 3]}}]}"#,
        )
        .unwrap();
        assert_eq!(
            lookup(&doc, "configs.0.speedup").and_then(|v| v.as_f64()),
            Some(2.5)
        );
        assert_eq!(
            lookup(&doc, "configs.1.nested.x.2").and_then(|v| v.as_f64()),
            Some(3.0)
        );
        assert!(lookup(&doc, "configs.5.speedup").is_none());
        assert!(lookup(&doc, "configs.0.missing").is_none());
    }

    fn metric(better_higher: bool, value: f64, tol: f64) -> Metric {
        Metric {
            name: "m".into(),
            file: "f".into(),
            path: "p".into(),
            higher_is_better: better_higher,
            value,
            tolerance: tol,
        }
    }

    #[test]
    fn higher_is_better_floors() {
        let m = metric(true, 2.0, 0.25);
        assert!(check(&m, 2.4).is_ok());
        assert!(check(&m, 1.51).is_ok()); // within 25 %
        assert!(check(&m, 1.49).is_err()); // beyond 25 %
    }

    #[test]
    fn lower_is_better_ceilings() {
        let m = metric(false, 100.0, 0.25);
        assert!(check(&m, 80.0).is_ok());
        assert!(check(&m, 124.0).is_ok());
        assert!(check(&m, 126.0).is_err());
    }

    #[test]
    fn baseline_parsing_and_validation() {
        let doc = JsonValue::parse(
            r#"{"tolerance": 0.2, "metrics": [
                {"name": "a", "file": "F.json", "path": "x.0", "better": "higher", "value": 1.5},
                {"name": "b", "file": "F.json", "path": "y", "better": "lower", "value": 9.0,
                 "tolerance": 0.5}
            ]}"#,
        )
        .unwrap();
        let ms = parse_baseline(&doc).unwrap();
        assert_eq!(ms.len(), 2);
        assert!(ms[0].higher_is_better);
        assert_eq!(ms[0].tolerance, 0.2); // file-level default
        assert_eq!(ms[1].tolerance, 0.5); // per-metric override
        let bad = JsonValue::parse(
            r#"{"metrics": [{"name": "a", "file": "F", "path": "x", "better": "sideways",
                             "value": 1.0}]}"#,
        )
        .unwrap();
        assert!(parse_baseline(&bad).is_err());
    }

    #[test]
    fn end_to_end_gate_on_temp_files() {
        let dir = std::env::temp_dir().join(format!("bench_gate_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("BENCH_x.json"),
            r#"{"configs": [{"speedup": 2.0}]}"#,
        )
        .unwrap();
        let baseline = dir.join("baseline.json");
        std::fs::write(
            &baseline,
            r#"{"tolerance": 0.25, "metrics": [
                {"name": "x speedup", "file": "BENCH_x.json",
                 "path": "configs.0.speedup", "better": "higher", "value": 1.0}
            ]}"#,
        )
        .unwrap();
        assert!(run(&baseline, &dir).is_ok());
        // a regression beyond tolerance fails
        std::fs::write(
            dir.join("BENCH_x.json"),
            r#"{"configs": [{"speedup": 0.5}]}"#,
        )
        .unwrap();
        assert!(run(&baseline, &dir).is_err());
        // a missing artifact fails (never silently passes)
        std::fs::remove_file(dir.join("BENCH_x.json")).unwrap();
        assert!(run(&baseline, &dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
