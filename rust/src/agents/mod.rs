//! Local agents: the per-machine daemons of §3.
//!
//! In the paper each machine runs a local agent that (a) schedules its
//! port's flows according to the **last schedule received** from the
//! coordinator, complying until a new one arrives, and (b) reports
//! upward — Philae agents only report *flow completions* (with the length
//! if the flow was a pilot), while Aalo agents additionally ship
//! per-coflow byte counts every δ. That asymmetry is the whole of Table 1
//! and drives Tables 3/4/6.
//!
//! [`AgentSim`] emulates one machine for the live tokio service
//! (`crate::service`): it holds the flows whose *source* is its port,
//! advances them at the last scheduled rates in (scaled) wall-clock time,
//! and emits completion reports and byte updates over channels.

use crate::{Bytes, CoflowId, FlowId, PortId, Time};

/// Agent → coordinator messages.
#[derive(Debug, Clone, PartialEq)]
pub enum AgentMsg {
    /// A flow finished; `size` is its measured length (used by the
    /// coordinator only when `pilot` — Philae's sampling measurement).
    FlowComplete {
        agent: PortId,
        flow: FlowId,
        coflow: CoflowId,
        size: Bytes,
        pilot: bool,
        at: Time,
    },
    /// Periodic per-coflow bytes-sent report (Aalo only).
    ByteUpdate {
        agent: PortId,
        coflow: CoflowId,
        bytes_sent: Bytes,
    },
}

/// Coordinator → agent messages.
#[derive(Debug, Clone, PartialEq)]
pub enum CoordMsg {
    /// New rates for this agent's flows; flows absent from the list stall.
    NewSchedule { rates: Vec<(FlowId, f64)> },
    /// A flow is newly assigned to this agent (src side).
    AddFlow {
        flow: FlowId,
        coflow: CoflowId,
        size: Bytes,
        pilot: bool,
    },
    /// Drain and stop.
    Shutdown,
}

/// One emulated machine.
#[derive(Debug)]
pub struct AgentSim {
    pub port: PortId,
    flows: Vec<AgentFlow>,
    /// Local wall of received schedules (diagnostics).
    pub schedules_received: u64,
}

#[derive(Debug, Clone)]
struct AgentFlow {
    id: FlowId,
    coflow: CoflowId,
    size: Bytes,
    sent: Bytes,
    rate: f64,
    pilot: bool,
}

impl AgentSim {
    pub fn new(port: PortId) -> Self {
        AgentSim {
            port,
            flows: Vec::new(),
            schedules_received: 0,
        }
    }

    pub fn add_flow(&mut self, flow: FlowId, coflow: CoflowId, size: Bytes, pilot: bool) {
        self.flows.push(AgentFlow {
            id: flow,
            coflow,
            size,
            sent: 0.0,
            rate: 0.0,
            pilot,
        });
    }

    /// Apply a schedule: set listed rates, stall everything else — the
    /// "comply with the last schedule until a new one is received" rule.
    pub fn apply_schedule(&mut self, rates: &[(FlowId, f64)]) {
        self.schedules_received += 1;
        for f in &mut self.flows {
            f.rate = 0.0;
        }
        for &(fid, r) in rates {
            if let Some(f) = self.flows.iter_mut().find(|f| f.id == fid) {
                f.rate = r;
            }
        }
    }

    /// Advance local flows by `dt` seconds; returns completion reports.
    pub fn advance(&mut self, dt: Time, now: Time) -> Vec<AgentMsg> {
        let mut out = Vec::new();
        let port = self.port;
        for f in &mut self.flows {
            if f.rate > 0.0 {
                f.sent = (f.sent + f.rate * dt).min(f.size);
            }
        }
        self.flows.retain(|f| {
            if f.size - f.sent <= crate::EPS {
                out.push(AgentMsg::FlowComplete {
                    agent: port,
                    flow: f.id,
                    coflow: f.coflow,
                    size: f.size,
                    pilot: f.pilot,
                    at: now,
                });
                false
            } else {
                true
            }
        });
        out
    }

    /// Seconds until this agent's next local completion (None if stalled).
    pub fn next_completion(&self) -> Option<Time> {
        self.flows
            .iter()
            .filter(|f| f.rate > 0.0)
            .map(|f| (f.size - f.sent) / f.rate)
            .min_by(f64::total_cmp)
    }

    /// Aalo-style per-coflow byte updates for the current instant.
    pub fn byte_updates(&self) -> Vec<AgentMsg> {
        let mut per_coflow: Vec<(CoflowId, Bytes)> = Vec::new();
        for f in &self.flows {
            match per_coflow.iter_mut().find(|(c, _)| *c == f.coflow) {
                Some(e) => e.1 += f.sent,
                None => per_coflow.push((f.coflow, f.sent)),
            }
        }
        per_coflow
            .into_iter()
            .map(|(coflow, bytes_sent)| AgentMsg::ByteUpdate {
                agent: self.port,
                coflow,
                bytes_sent,
            })
            .collect()
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_compliance_and_completion() {
        let mut a = AgentSim::new(3);
        a.add_flow(0, 0, 100.0, true);
        a.add_flow(1, 0, 50.0, false);
        // no schedule yet: nothing moves
        assert!(a.advance(1.0, 1.0).is_empty());
        a.apply_schedule(&[(0, 10.0)]);
        assert_eq!(a.next_completion(), Some(10.0));
        let msgs = a.advance(10.0, 11.0);
        assert_eq!(msgs.len(), 1);
        match &msgs[0] {
            AgentMsg::FlowComplete { flow, size, pilot, agent, .. } => {
                assert_eq!(*flow, 0);
                assert_eq!(*size, 100.0);
                assert!(*pilot);
                assert_eq!(*agent, 3);
            }
            m => panic!("unexpected {m:?}"),
        }
        assert_eq!(a.active_flows(), 1);
    }

    #[test]
    fn new_schedule_stalls_unlisted_flows() {
        let mut a = AgentSim::new(0);
        a.add_flow(0, 0, 100.0, false);
        a.add_flow(1, 1, 100.0, false);
        a.apply_schedule(&[(0, 10.0), (1, 10.0)]);
        a.advance(1.0, 1.0);
        a.apply_schedule(&[(1, 20.0)]); // flow 0 dropped from schedule
        a.advance(1.0, 2.0);
        let upd = a.byte_updates();
        assert!(upd.contains(&AgentMsg::ByteUpdate { agent: 0, coflow: 0, bytes_sent: 10.0 }));
        assert!(upd.contains(&AgentMsg::ByteUpdate { agent: 0, coflow: 1, bytes_sent: 30.0 }));
    }

    #[test]
    fn byte_updates_aggregate_per_coflow() {
        let mut a = AgentSim::new(0);
        a.add_flow(0, 7, 100.0, false);
        a.add_flow(1, 7, 100.0, false);
        a.apply_schedule(&[(0, 5.0), (1, 5.0)]);
        a.advance(2.0, 2.0);
        let upd = a.byte_updates();
        assert_eq!(upd.len(), 1);
        assert_eq!(
            upd[0],
            AgentMsg::ByteUpdate { agent: 0, coflow: 7, bytes_sent: 20.0 }
        );
    }
}
