//! Property tests of the multi-coordinator cluster
//! (`coordinator::cluster`): structural invariants must hold at every
//! scheduling round of randomized end-to-end runs, under the most
//! migration-happy configuration we can build (reconcile every round,
//! near-zero imbalance threshold):
//!
//! * **lease conservation** — per port and direction, Σ over shards of the
//!   leased capacity equals the fabric capacity;
//! * **unique ownership** — every active coflow is owned by exactly one
//!   shard, the owner map agrees with the shard lists, and migration never
//!   produces double ownership;
//! * **feasibility** — the union of the K shards' grants never
//!   oversubscribes a port;
//! * **liveness** — every coflow finishes even while coflows migrate
//!   between shards mid-flight.
//!
//! The K=1 bit-identity oracle lives in `cct_equivalence.rs`.

use std::sync::atomic::{AtomicU64, Ordering};

use philae::coordinator::{
    ClusterConfig, CoordinatorCluster, SchedulerConfig, SchedulerKind,
};
use philae::fabric::Fabric;
use philae::sim::{world_from_trace, SimConfig, Simulation};
use philae::trace::TraceSpec;
use philae::util::prop;

/// A migration-happy cluster config with per-round invariant validation.
fn aggressive(k: usize) -> ClusterConfig {
    ClusterConfig {
        coordinators: k,
        reconcile_every: 1,
        max_migrations_per_round: 8,
        imbalance_threshold: 1.05,
        lease_floor_frac: 0.05,
        validate: true,
    }
}

#[test]
fn randomized_runs_hold_cluster_invariants_and_finish() {
    // migrations across the whole sweep — asserted non-zero at the end so
    // the property actually exercises the migration path
    static MIGRATIONS: AtomicU64 = AtomicU64::new(0);
    static RECONCILES: AtomicU64 = AtomicU64::new(0);

    prop::for_all(16, |rng| {
        let ports = rng.range_inclusive(6, 16);
        let coflows = rng.range_inclusive(8, 28);
        let k = rng.range_inclusive(2, 4);
        let seed = rng.next_u64();
        let kind = if rng.chance(0.5) {
            SchedulerKind::Philae
        } else {
            SchedulerKind::Aalo
        };
        let trace = TraceSpec::tiny(ports, coflows).seed(seed).generate();
        let cfg = SchedulerConfig::default();
        let mut cluster = CoordinatorCluster::new(kind, &trace, &cfg, aggressive(k));
        let sim_cfg = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };
        // `validate: true` asserts lease conservation + unique ownership
        // inside every scheduling round of the whole run
        let res = Simulation::run_with_cluster(&trace, &mut cluster, &cfg, &sim_cfg);
        for (i, &cct) in res.ccts.iter().enumerate() {
            assert!(
                cct.is_finite() && cct > 0.0,
                "{kind:?} K={k} seed {seed}: coflow {i} never finished"
            );
        }
        MIGRATIONS.fetch_add(cluster.migrations(), Ordering::Relaxed);
        RECONCILES.fetch_add(cluster.reconciliations(), Ordering::Relaxed);
    });

    assert!(
        RECONCILES.load(Ordering::Relaxed) > 0,
        "no reconciliation ran across the whole sweep — the property is vacuous"
    );
    assert!(
        MIGRATIONS.load(Ordering::Relaxed) > 0,
        "no migration happened across the whole sweep — the property is vacuous"
    );
}

#[test]
fn lease_conservation_exact_on_heterogeneous_fabrics() {
    prop::for_all(32, |rng| {
        let ports = rng.range_inclusive(4, 20);
        let k = rng.range_inclusive(2, 5);
        let coflows = rng.range_inclusive(4, 16);
        let trace = TraceSpec::tiny(ports, coflows).seed(rng.next_u64()).generate();
        let cfg = SchedulerConfig::default();
        let mut world = world_from_trace(&trace);
        // heterogeneous, including dead directions
        let cap = |rng: &mut philae::util::Rng| {
            if rng.chance(0.1) {
                0.0
            } else {
                rng.uniform(10.0, 1000.0)
            }
        };
        let ups: Vec<f64> = (0..ports).map(|_| cap(rng)).collect();
        let downs: Vec<f64> = (0..ports).map(|_| cap(rng)).collect();
        world.fabric = Fabric::heterogeneous(ups, downs);

        let mut cluster = CoordinatorCluster::new(
            SchedulerKind::Philae,
            &trace,
            &cfg,
            aggressive(k),
        );
        for cid in 0..trace.coflows.len() {
            world.active.push(cid);
            cluster.on_arrival(cid, &mut world);
        }
        // several reconcile + compute rounds: leases must stay conserved
        // (validate inside compute) and exactly per-port summable here
        for _ in 0..3 {
            cluster.reconcile_now(&mut world);
            cluster.compute(&mut world, false);
            for p in 0..world.fabric.num_ports {
                let up: f64 = (0..k).map(|s| cluster.lease(s).up_capacity[p]).sum();
                let cap = world.fabric.up_capacity[p];
                assert!(
                    (up - cap).abs() <= 1e-9 * cap.max(1.0),
                    "uplink {p}: Σ leases {up} != {cap}"
                );
                let down: f64 = (0..k).map(|s| cluster.lease(s).down_capacity[p]).sum();
                let cap = world.fabric.down_capacity[p];
                assert!(
                    (down - cap).abs() <= 1e-9 * cap.max(1.0),
                    "downlink {p}: Σ leases {down} != {cap}"
                );
            }
        }
    });
}

/// Crash-failover property: killing a shard and restoring its scheduler
/// (from a sealed cluster checkpoint, or cold with none) must leave every
/// structural invariant intact — per-port lease sums still equal the
/// fabric capacity, ownership stays unique, and the next compute round
/// still produces feasible grants. The restore deliberately keeps the
/// shard's current lease and ownership, so this is conservation *by
/// construction* — the test pins that construction.
#[test]
fn shard_restore_preserves_leases_and_ownership() {
    prop::for_all(16, |rng| {
        let ports = rng.range_inclusive(6, 14);
        let coflows = rng.range_inclusive(8, 20);
        let k = rng.range_inclusive(2, 4);
        let seed = rng.next_u64();
        let kind = if rng.chance(0.5) {
            SchedulerKind::Philae
        } else {
            SchedulerKind::Aalo
        };
        let trace = TraceSpec::tiny(ports, coflows).seed(seed).generate();
        let cfg = SchedulerConfig::default();
        let mut world = world_from_trace(&trace);
        let mut cluster = CoordinatorCluster::new(kind, &trace, &cfg, aggressive(k));
        for cid in 0..trace.coflows.len() {
            world.active.push(cid);
            cluster.on_arrival(cid, &mut world);
        }
        cluster.compute(&mut world, false);
        cluster.reconcile_now(&mut world); // leases now demand-weighted
        let ckpt = cluster.checkpoint(&mut world);
        let victim = rng.below(k);
        let with_ckpt = rng.chance(0.5);
        let restored = cluster.kill_and_restore_shard(
            victim,
            &trace,
            &cfg,
            with_ckpt.then_some(ckpt.as_str()),
            &mut world,
        );
        restored.unwrap_or_else(|e| panic!("{kind:?} K={k} seed {seed}: restore failed: {e}"));
        cluster.check_invariants(&world);
        for p in 0..world.fabric.num_ports {
            let up: f64 = (0..k).map(|s| cluster.lease(s).up_capacity[p]).sum();
            let cap = world.fabric.up_capacity[p];
            assert!(
                (up - cap).abs() <= 1e-9 * cap.max(1.0),
                "{kind:?} K={k} seed {seed}: uplink {p} leaked across restore: {up} != {cap}"
            );
            let down: f64 = (0..k).map(|s| cluster.lease(s).down_capacity[p]).sum();
            let cap = world.fabric.down_capacity[p];
            assert!(
                (down - cap).abs() <= 1e-9 * cap.max(1.0),
                "{kind:?} K={k} seed {seed}: downlink {p} leaked across restore: {down} != {cap}"
            );
        }
        cluster.compute(&mut world, false);
        cluster.check_invariants(&world);
        assert!(
            !cluster.grants().is_empty(),
            "{kind:?} K={k} seed {seed}: restored cluster stopped granting"
        );
    });
}

#[test]
fn migration_preserves_unique_ownership() {
    prop::for_all(24, |rng| {
        let ports = rng.range_inclusive(6, 14);
        let coflows = rng.range_inclusive(6, 20);
        let k = rng.range_inclusive(2, 4);
        let trace = TraceSpec::tiny(ports, coflows).seed(rng.next_u64()).generate();
        let cfg = SchedulerConfig::default();
        let mut world = world_from_trace(&trace);
        let mut cluster = CoordinatorCluster::new(
            SchedulerKind::Philae,
            &trace,
            &cfg,
            aggressive(k),
        );
        for cid in 0..trace.coflows.len() {
            world.active.push(cid);
            cluster.on_arrival(cid, &mut world);
        }
        // force several migration-heavy reconciliation rounds, draining
        // some flows in between so remaining-bytes demand keeps shifting
        for round in 0..4 {
            cluster.reconcile_now(&mut world);
            cluster.check_invariants(&world);
            // every active coflow owned exactly once, across migrations
            let mut owners = vec![0usize; trace.coflows.len()];
            for s in 0..k {
                for &cid in cluster.owned(s) {
                    owners[cid] += 1;
                    assert_eq!(cluster.owner_of(cid), Some(s), "round {round}, coflow {cid}");
                }
            }
            for &cid in &world.active {
                assert_eq!(owners[cid], 1, "round {round}: coflow {cid} owned {}x", owners[cid]);
            }
            // drain a random prefix of some coflow's flows
            let cid = rng.below(trace.coflows.len());
            let flows = world.coflows[cid].flows.clone();
            for &f in flows.iter().take(rng.range_inclusive(0, flows.len())) {
                let fl = &mut world.flows[f];
                fl.sent = fl.size * rng.uniform(0.2, 1.0);
            }
        }
    });
}
