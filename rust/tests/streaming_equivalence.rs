//! Equivalence pins for the streaming engine path (ROADMAP item 3).
//!
//! The streamed run admits coflows from a bounded-memory
//! [`ArrivalStream`] and retires per-flow state as coflows finish; this
//! suite pins it **bit-identical** to the materialized engine for every
//! registered scheduler, through the K=1 cluster frontend, and across
//! generator scenarios — plus determinism pins for the scenario library
//! and sanity bounds for the optimality-gap oracle.
//!
//! `account_delta: Some(1e18)` everywhere: one giant accounting interval,
//! so measured wall time never couples into the event history (same
//! convention as `cct_equivalence.rs`).

use philae::analysis::{cct_lower_bound_default, optimality_gap};
use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::sim::{SimConfig, Simulation};
use philae::trace::{ArrivalStream, CoflowArrival, TraceSpec, TraceStream};

fn sim_cfg() -> SimConfig {
    SimConfig { account_delta: Some(1e18), ..SimConfig::default() }
}

fn assert_bit_identical(
    kind: SchedulerKind,
    a: &philae::sim::SimResult,
    b: &philae::sim::SimResult,
) {
    assert_eq!(a.ccts.len(), b.ccts.len(), "{kind:?}: coflow count");
    for (i, (x, y)) in a.ccts.iter().zip(b.ccts.iter()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{kind:?}: CCT diverged at coflow {i} ({x} vs {y})"
        );
    }
    assert_eq!(a.rate_calcs, b.rate_calcs, "{kind:?}: rate calcs");
    assert_eq!(a.update_msgs, b.update_msgs, "{kind:?}: update messages");
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{kind:?}: makespan");
}

#[test]
fn streamed_matches_materialized_for_every_scheduler() {
    let spec = TraceSpec::tiny(10, 30).seed(7);
    let trace = spec.generate();
    let cfg = SchedulerConfig::default();
    for &kind in SchedulerKind::all() {
        let mut sched = kind.build(&trace, &cfg);
        let materialized = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg());
        let mut stream = spec.stream();
        let streamed = Simulation::run_stream(&mut stream, kind, &cfg, &sim_cfg());
        assert_bit_identical(kind, &streamed, &materialized);
    }
}

#[test]
fn streamed_trace_replay_matches_generator_stream() {
    // the two ArrivalStream impls must drive the engine identically:
    // SpecStream regenerates from the spec, TraceStream replays the
    // materialized trace in arrival order
    let spec = TraceSpec::fb_like(12, 40).seed(11);
    let trace = spec.generate();
    let cfg = SchedulerConfig::default();
    for kind in [SchedulerKind::Philae, SchedulerKind::Sebf, SchedulerKind::Scf] {
        let mut gen_stream = spec.stream();
        let a = Simulation::run_stream(&mut gen_stream, kind, &cfg, &sim_cfg());
        let mut replay = TraceStream::new(&trace);
        let b = Simulation::run_stream(&mut replay, kind, &cfg, &sim_cfg());
        assert_bit_identical(kind, &a, &b);
    }
}

#[test]
fn streamed_cluster_k1_matches_single_coordinator() {
    let spec = TraceSpec::tiny(8, 25).seed(13);
    let trace = spec.generate();
    let cfg = SchedulerConfig::default();
    let kind = SchedulerKind::Philae;
    let mut sched = kind.build(&trace, &cfg);
    let single = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg());
    let mut stream = spec.stream();
    let clustered = Simulation::run_stream_cluster(&mut stream, kind, &cfg, &sim_cfg());
    assert_bit_identical(kind, &clustered, &single);
}

#[test]
fn streamed_scenarios_match_materialized() {
    // every library scenario, streamed vs materialized, one cheap kind —
    // covers the Ring expansion path (all-reduce) and the diurnal clock
    let cfg = SchedulerConfig::default();
    for name in TraceSpec::scenario_names() {
        let spec = TraceSpec::scenario(name, 12, 25).expect("registry name").seed(17);
        let trace = spec.generate();
        let mut sched = SchedulerKind::Fifo.build(&trace, &cfg);
        let materialized = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg());
        let mut stream = spec.stream();
        let streamed = Simulation::run_stream(&mut stream, SchedulerKind::Fifo, &cfg, &sim_cfg());
        assert!(
            materialized.ccts.iter().all(|c| c.is_finite()),
            "{name}: unfinished coflows"
        );
        assert_bit_identical(SchedulerKind::Fifo, &streamed, &materialized);
    }
}

#[test]
fn scenario_library_is_deterministic_and_distinct() {
    // same name + seed → byte-equal traces; each scenario has its own RNG
    // stream, so adding one can never perturb another
    for name in TraceSpec::scenario_names() {
        let a = TraceSpec::scenario(name, 20, 30).unwrap().generate();
        let b = TraceSpec::scenario(name, 20, 30).unwrap().generate();
        assert_eq!(a.coflows, b.coflows, "{name}: coflow specs must be reproducible");
        assert_eq!(a.flows, b.flows, "{name}: flow specs must be reproducible");
        assert!(!a.coflows.is_empty(), "{name}: empty scenario");
    }
    // alias spellings resolve to the same spec
    let a = TraceSpec::scenario("all-reduce", 16, 10).unwrap().generate();
    let b = TraceSpec::scenario("all_reduce", 16, 10).unwrap().generate();
    assert_eq!(a.flows, b.flows);
    assert!(TraceSpec::scenario("no-such-scenario", 16, 10).is_none());
}

#[test]
fn scenario_shapes_match_their_stories() {
    // incast: every coflow funnels into exactly one reducer
    let incast = TraceSpec::incast(32, 20).generate();
    for c in &incast.coflows {
        assert_eq!(c.receivers.len(), 1, "incast coflow {} has fan-out", c.id);
        assert!(c.senders.len() >= 2, "incast coflow {} is not a fan-in", c.id);
    }
    // all-reduce: ring pass — every participant sends and receives once,
    // equal bytes per link
    let ring = TraceSpec::all_reduce(32, 20).generate();
    for c in &ring.coflows {
        assert_eq!(c.senders.len(), c.receivers.len(), "ring coflow {}", c.id);
        assert_eq!(c.flows.len(), c.senders.len(), "one flow per link");
        let first = ring.flows[c.flows[0]].size;
        for &f in &c.flows {
            assert_eq!(ring.flows[f].size, first, "unequal ring chunks");
        }
    }
}

#[test]
fn streamed_run_bounds_live_flow_state() {
    // the allocated flow table must track the concurrent working set
    // (recycled slots), not the cumulative arrival count
    let spec = TraceSpec::tiny(6, 60).seed(23);
    let mut probe = spec.stream();
    let mut arr = CoflowArrival::default();
    let mut total_flows = 0usize;
    while probe.next_arrival(&mut arr) {
        total_flows += arr.flows.len();
    }
    let mut stream = spec.stream();
    let res = Simulation::run_stream(
        &mut stream,
        SchedulerKind::Fifo,
        &SchedulerConfig::default(),
        &sim_cfg(),
    );
    assert_eq!(res.ccts.len(), 60);
    assert!(
        res.flow_slots < total_flows,
        "no retirement happened: {} slots allocated for {} streamed flows",
        res.flow_slots,
        total_flows
    );
}

#[test]
fn oracle_bound_is_sane_across_kinds_and_scenarios() {
    let cfg = SchedulerConfig::default();
    for name in ["fb-like", "incast", "adversarial-skew"] {
        let trace = TraceSpec::scenario(name, 16, 30).unwrap().generate();
        let lb = cct_lower_bound_default(&trace);
        assert!(lb.avg_cct() > 0.0, "{name}: vacuous bound");
        assert!(lb.avg_cct().is_finite(), "{name}: divergent bound");
        let sum_ideal: f64 = lb.ideal.iter().sum();
        assert!(
            lb.total_cct >= sum_ideal - 1e-9,
            "{name}: machine relaxation below Σ ideal"
        );
        for &kind in SchedulerKind::all() {
            let mut sched = kind.build(&trace, &cfg);
            let res = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg());
            let gap = optimality_gap(res.avg_cct(), lb.avg_cct());
            assert!(
                gap >= -1e-6,
                "{name}/{kind:?}: beat the lower bound (gap {gap})"
            );
        }
    }
}
