//! Asserts the acceptance bar of the zero-allocation reallocation engine:
//! once warmed, the steady-state hot path — incremental `order_into`, the
//! scratch-based `allocate_into`, and the mark-based `apply_grants` (the
//! exact pipeline `sim::Engine::reallocate` runs per event) — performs
//! **zero heap allocations**, counted by a wrapping global allocator.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use philae::coordinator::{rate, Plan, Scheduler, SchedulerConfig, SchedulerKind};
use philae::sim::world_from_trace;
use philae::trace::TraceSpec;

struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_COUNT.load(Ordering::SeqCst)
}

// NB: single #[test] on purpose — the libtest harness runs tests of one
// binary in parallel threads, and a concurrent test's allocations would
// corrupt the global counter window.
#[test]
fn steady_state_reallocation_performs_zero_heap_allocations() {
    let trace = TraceSpec::fb_like(60, 80).seed(3).generate();
    for &kind in SchedulerKind::all() {
        let mut world = world_from_trace(&trace);
        world.active = (0..trace.coflows.len()).collect();
        let cfg = SchedulerConfig::default();
        let mut sched = kind.build(&trace, &cfg);
        for cid in 0..trace.coflows.len() {
            sched.on_arrival(cid, &mut world);
        }
        // Philae variants: force estimation so the scheduled lane (the
        // sorted structure) is the one exercised.
        for cid in 0..trace.coflows.len() {
            world.coflows[cid].phase = philae::coflow::CoflowPhase::Running;
            world.coflows[cid].est_size = Some(world.coflows[cid].total_bytes);
        }
        let mut plan = Plan::default();
        let mut scratch = rate::AllocScratch::new();
        // Warm-up: grow every reusable buffer to its high-water mark and
        // settle the incremental caches.
        for _ in 0..3 {
            sched.order_into(&world, &mut plan);
            rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut scratch);
            rate::apply_grants(&mut world.flows, &world.coflows, &plan, scratch.grants());
        }
        let before = allocs();
        for _ in 0..50 {
            sched.order_into(&world, &mut plan);
            rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut scratch);
            rate::apply_grants(&mut world.flows, &world.coflows, &plan, scratch.grants());
        }
        let after = allocs();
        assert_eq!(
            after - before,
            0,
            "{kind:?}: steady-state order+allocate+apply allocated {} times",
            after - before
        );
    }
    compat_wrappers_still_allocate_but_agree();
    obs_record_paths_are_allocation_free();
}

fn obs_record_paths_are_allocation_free() {
    // The observability hot paths must be free to leave always-on:
    // `LogHistogram::record` is two array index bumps into a fixed
    // 64×64 bucket grid, and `Recorder::push` writes into a ring whose
    // backing store is fully reserved at construction — neither may
    // touch the heap once built.
    use philae::obs::{Event, EventKind, LogHistogram, Recorder};

    let mut hist = LogHistogram::new();
    let mut ring = Recorder::new(256);
    let ev = Event {
        t: 1.0,
        wall_ns: 0,
        seq: 0,
        shard: 0,
        kind: EventKind::Scheduled,
        coflow: 3,
        a: 0,
        b: 0,
    };
    // warm (construction already reserved everything, but keep the
    // window convention of the main test)
    hist.record(17);
    ring.push(ev);

    let before = allocs();
    for i in 0..10_000u64 {
        hist.record(i * 131 + 1);
        ring.push(Event { seq: i, ..ev });
    }
    // percentile queries walk the fixed grid — also alloc-free
    let p = hist.percentile(0.99);
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "obs record path allocated {} times",
        after - before
    );
    assert!(p > 0, "p99 of a populated histogram must be nonzero");
    assert_eq!(ring.len(), 256, "ring must sit at capacity after wraparound");
    assert!(ring.dropped() > 0, "wraparound must count drops");
}

fn compat_wrappers_still_allocate_but_agree() {
    // sanity check on the counter itself: the compat `allocate` wrapper
    // builds a fresh scratch, which must show up as heap traffic.
    let trace = TraceSpec::tiny(8, 10).seed(1).generate();
    let mut world = world_from_trace(&trace);
    world.active = (0..trace.coflows.len()).collect();
    let cfg = SchedulerConfig::default();
    let mut sched = SchedulerKind::Fifo.build(&trace, &cfg);
    for cid in 0..trace.coflows.len() {
        sched.on_arrival(cid, &mut world);
    }
    let plan = sched.order(&world);
    let before = allocs();
    let alloc = rate::allocate(&world.fabric, &world.flows, &world.coflows, &plan);
    assert!(allocs() > before, "fresh-scratch path should allocate");
    let mut scratch = rate::AllocScratch::new();
    rate::allocate_into(&world.fabric, &world.flows, &world.coflows, &plan, &mut scratch);
    assert_eq!(scratch.grants(), &alloc.grants[..]);
    assert_eq!(scratch.visited(), alloc.visited);
}
