//! Chaos harness for coordinator crash-failover (`coordinator/recovery.rs`):
//!
//! * **Exact restore oracle** — killing the single coordinator at an event
//!   boundary and restoring it from a freshly sealed checkpoint must be
//!   **bit-identical** to the uninterrupted run, for *every* scheduler kind
//!   in the registry. This is the strongest correctness statement the
//!   checkpoint format can make: the sealed durable facts plus the physical
//!   world reconstruct the scheduler brain exactly.
//! * **Cluster chaos** — killing random shards mid-run through the chaos
//!   driver must leave every structural invariant intact, finish every
//!   coflow, and degrade CCT only boundedly (the crash model loses learned
//!   scheduler state, never bytes in flight).
//! * **SLO preservation** — a dcoflow run that meets every admitted
//!   deadline without chaos must still expire nothing when shards crash:
//!   admitted certificates are durable facts and survive the restore.
//! * **Live-service supervisor** — the threaded service with checkpoint +
//!   chaos + agent-loss watchdog armed still completes the trace, counts
//!   one recovery per injected crash, and persists unsealable checkpoints.

use philae::coordinator::{
    unseal, ClusterConfig, CoordinatorCluster, SchedulerConfig, SchedulerKind,
};
use philae::service::{run_service, ServiceConfig};
use philae::sim::{SimConfig, SimResult, Simulation};
use philae::trace::{DeadlineModel, TraceSpec};

/// Wall-time decoupled sim config: the §4.3 deadline model never couples
/// measured wall time into the event history, so histories are replayable
/// bit-for-bit.
fn decoupled() -> SimConfig {
    SimConfig { account_delta: Some(1e18), ..SimConfig::default() }
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, what: &str) {
    assert_eq!(a.ccts.len(), b.ccts.len(), "{what}: coflow count");
    for (i, (x, y)) in a.ccts.iter().zip(b.ccts.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: CCT diverged at coflow {i}");
    }
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{what}: makespan");
    assert_eq!(a.rate_calcs, b.rate_calcs, "{what}: rate calcs");
    assert_eq!(a.rate_msgs, b.rate_msgs, "{what}: rate msgs");
    assert_eq!(a.update_msgs, b.update_msgs, "{what}: update msgs");
    assert_eq!(a.deadline, b.deadline, "{what}: SLO accounting");
}

/// The tentpole pin: checkpoint-then-restore at any event boundary is
/// bit-identical to never crashing, for all registry kinds. A deadline
/// trace is used so the SLO accounting path (admission verdicts, expiry)
/// is exercised through the crash for dcoflow too.
#[test]
fn restore_is_bit_identical_for_every_scheduler_kind() {
    let trace = TraceSpec::fb_like(50, 60).seed(5).with_deadline_tightness(2.0).generate();
    let cfg = SchedulerConfig::default();
    let sim_cfg = decoupled();
    for &kind in SchedulerKind::all() {
        let mut sched = kind.build(&trace, &cfg);
        let plain = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg);
        // a prime period so crashes land on many distinct boundary shapes
        let (restored, restores) = Simulation::run_with_restore(&trace, kind, &cfg, &sim_cfg, 7);
        assert!(restores > 0, "{kind:?}: crash injection never fired — the pin is vacuous");
        assert_bit_identical(&plain, &restored, kind.as_str());
    }
}

/// Crashing every few events instead of every few dozen must not change
/// the answer either — restore composes with itself.
#[test]
fn repeated_rapid_restores_stay_bit_identical() {
    let trace = TraceSpec::fb_like(30, 40).seed(9).generate();
    let cfg = SchedulerConfig::default();
    let sim_cfg = decoupled();
    for &kind in &[SchedulerKind::Philae, SchedulerKind::Saath, SchedulerKind::PhilaeEcMulti] {
        let mut sched = kind.build(&trace, &cfg);
        let plain = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg);
        let (restored, restores) = Simulation::run_with_restore(&trace, kind, &cfg, &sim_cfg, 2);
        assert!(restores > 10, "{kind:?}: only {restores} restores at every=2");
        assert_bit_identical(&plain, &restored, kind.as_str());
    }
}

fn chaos_cluster_cfg(k: usize) -> ClusterConfig {
    ClusterConfig {
        coordinators: k,
        reconcile_every: 4,
        max_migrations_per_round: 4,
        imbalance_threshold: 1.5,
        lease_floor_frac: 0.05,
        // asserts lease conservation + unique ownership inside every
        // scheduling round, crashes included
        validate: true,
    }
}

/// End-to-end cluster chaos: shards die and are restored from the chaos
/// driver's own checkpoints mid-run. Everything must finish, invariants
/// hold every round (`validate: true`), and the CCT cost of losing learned
/// scheduler state stays bounded — the crash model never loses bytes in
/// flight, so degradation is a re-learning cost, not a restart.
#[test]
fn cluster_chaos_finishes_with_bounded_cct_degradation() {
    let trace = TraceSpec::tiny(12, 30).seed(11).generate();
    let cfg = SchedulerConfig::default();
    let sim_cfg = decoupled();
    for &kind in &[SchedulerKind::Philae, SchedulerKind::Aalo] {
        let mut baseline = CoordinatorCluster::new(kind, &trace, &cfg, chaos_cluster_cfg(3));
        let base = Simulation::run_with_cluster(&trace, &mut baseline, &cfg, &sim_cfg);

        let mut chaotic = CoordinatorCluster::new(kind, &trace, &cfg, chaos_cluster_cfg(3));
        chaotic.set_chaos(&trace, &cfg, 2, 3, 42);
        let res = Simulation::run_with_cluster(&trace, &mut chaotic, &cfg, &sim_cfg);

        assert!(chaotic.chaos_checkpoints() > 0, "{kind:?}: no checkpoints sealed");
        assert!(chaotic.chaos_kills() > 0, "{kind:?}: no shards killed");
        for (i, &cct) in res.ccts.iter().enumerate() {
            assert!(
                cct.is_finite() && cct > 0.0,
                "{kind:?}: coflow {i} never finished under chaos"
            );
        }
        let base_mean = base.ccts.iter().sum::<f64>() / base.ccts.len() as f64;
        let chaos_mean = res.ccts.iter().sum::<f64>() / res.ccts.len() as f64;
        assert!(
            chaos_mean <= base_mean * 10.0,
            "{kind:?}: unbounded degradation — chaos mean CCT {chaos_mean} vs baseline {base_mean}"
        );
        assert!(res.makespan <= base.makespan * 10.0, "{kind:?}: unbounded makespan under chaos");
    }
}

/// SLO certificates are durable: on a workload where the no-chaos run
/// expires nothing, crashing shards mid-run must not expire anything
/// either. Admitted coflows' reservations are re-asserted by the restore
/// (conservative merge), so a crash can reject future arrivals but never
/// break a promise already made.
#[test]
fn cluster_chaos_preserves_slo_certificates() {
    let trace = TraceSpec::tiny(8, 14)
        .seed(14)
        .with_deadlines(DeadlineModel { tightness: 50.0, spread: 0.5, coverage: 1.0 })
        .generate();
    let cfg = SchedulerConfig::default();
    let sim_cfg = decoupled();
    let kind = SchedulerKind::Dcoflow;

    let mut baseline = CoordinatorCluster::new(kind, &trace, &cfg, chaos_cluster_cfg(2));
    let base = Simulation::run_with_cluster(&trace, &mut baseline, &cfg, &sim_cfg);
    assert_eq!(
        base.deadline.expired,
        0,
        "workload too tight for the preservation property to be meaningful"
    );
    assert!(base.deadline.admitted > 0, "nothing admitted — the pin is vacuous");

    let mut chaotic = CoordinatorCluster::new(kind, &trace, &cfg, chaos_cluster_cfg(2));
    chaotic.set_chaos(&trace, &cfg, 2, 3, 7);
    let res = Simulation::run_with_cluster(&trace, &mut chaotic, &cfg, &sim_cfg);
    assert!(chaotic.chaos_kills() > 0, "no shards killed — the pin is vacuous");
    assert_eq!(
        res.deadline.expired,
        0,
        "an admitted coflow expired across a crash: certificates were lost"
    );
    assert!(res.ccts.iter().all(|c| c.is_finite() && *c > 0.0));
}

fn chaos_svc(kind: SchedulerKind) -> ServiceConfig {
    ServiceConfig {
        kind,
        coordinators: 2,
        time_scale: 200.0, // fast replay: tiny traces finish in < 2 s wall
        checkpoint_every: 2,
        chaos_kill_every: 3,
        ..ServiceConfig::default()
    }
}

/// Live-service supervisor: crashes injected into the threaded coordinator
/// are each answered by exactly one recovery, the trace still completes,
/// and recovery wall time is measured.
#[test]
fn service_chaos_completes_trace_and_counts_recoveries() {
    // Philae exercises the adopt()-based rebuild, Aalo the
    // checkpoint-consuming generic restore.
    for kind in [SchedulerKind::Philae, SchedulerKind::Aalo] {
        let trace = TraceSpec::tiny(8, 14).seed(21).generate();
        let report = run_service(&trace, &chaos_svc(kind)).expect("chaos service run");
        assert_eq!(report.ccts.len(), trace.coflows.len());
        for (i, &cct) in report.ccts.iter().enumerate() {
            assert!(
                cct.is_finite() && cct > 0.0,
                "{kind:?}: coflow {i} unfinished under chaos: {cct}"
            );
        }
        assert!(report.checkpoints_written > 0, "{kind:?}: supervisor never checkpointed");
        assert!(report.crashes_injected > 0, "{kind:?}: chaos never fired");
        assert_eq!(
            report.recoveries,
            report.crashes_injected,
            "{kind:?}: a crash went unrecovered"
        );
        assert!(
            report.recovery_wall.n == report.recoveries,
            "{kind:?}: recovery latency not measured per recovery"
        );
    }
}

/// Persisted checkpoints survive the process: `shard_<s>.ckpt` files are
/// written atomically and unseal cleanly (checksum + version verified).
#[test]
fn service_persists_unsealable_checkpoints() {
    let dir = std::env::temp_dir().join(format!("philae_ckpt_{}", std::process::id()));
    let trace = TraceSpec::tiny(8, 12).seed(5).generate();
    let cfg = ServiceConfig {
        checkpoint_dir: Some(dir.clone()),
        ..chaos_svc(SchedulerKind::Philae)
    };
    let report = run_service(&trace, &cfg).expect("service run");
    assert!(report.checkpoints_written > 0);
    for s in 0..2 {
        let path = dir.join(format!("shard_{s}.ckpt"));
        let sealed = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("missing checkpoint {}: {e}", path.display()));
        let payload = unseal(&sealed).expect("persisted checkpoint must unseal");
        assert!(payload.get("kind").is_some(), "checkpoint lacks scheduler kind");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The agent-loss watchdog is armed but agents keep reporting: nothing
/// ages out spuriously on a healthy run, and the service still completes
/// with chaos on top.
#[test]
fn watchdog_does_not_fire_on_healthy_agents() {
    let trace = TraceSpec::tiny(8, 14).seed(33).generate();
    let cfg = ServiceConfig {
        // generous threshold: a healthy tiny-trace run never goes this quiet
        // while demand is pending
        agent_miss_intervals: 10_000,
        ..chaos_svc(SchedulerKind::Aalo)
    };
    let report = run_service(&trace, &cfg).expect("watchdog service run");
    assert!(report.ccts.iter().all(|c| c.is_finite() && *c > 0.0));
    assert_eq!(report.ports_aged_out, 0, "healthy agents were aged out");
    assert_eq!(report.ports_restored, 0);
}
