//! Property tests of the deadline subsystem's **admission certificate**
//! (`coordinator/dcoflow.rs`):
//!
//! 1. *Admitted coflows never expire* — an admitted coflow's feasibility
//!    certificate (its reserved per-port rates fit under capacity)
//!    continues to hold because later admissions can only claim leftover
//!    reservation room; under EDF + work-conserving greedy allocation the
//!    coflow then finishes by its deadline.
//! 2. *Rejected coflows never block admitted ones* — rejected coflows hold
//!    no reservation and sit behind every admitted coflow in the plan, so
//!    removing them from the schedule entirely (the `without_background`
//!    hook) must leave the admitted coflows' CCTs bit-identical.
//!
//! Both properties are exercised on seeded random SLO workloads and on a
//! hand-built contention scenario, and the expiry/consistency invariants
//! additionally run through the K=2 multi-coordinator cluster (leased
//! capacity, hash routing, migration hooks).

use philae::coordinator::{
    AdmissionState, DcoflowScheduler, SchedulerConfig, SchedulerKind,
};
use philae::sim::{SimConfig, Simulation};
use philae::trace::{DeadlineModel, Trace, TraceRecord, TraceSpec};
use philae::{GBPS, MB};

fn sim_cfg() -> SimConfig {
    // neutralize the §4.3 wall-time tick coupling for determinism
    SimConfig { account_delta: Some(1e18), ..SimConfig::default() }
}

fn slo_trace(ports: usize, coflows: usize, tightness: f64, seed: u64) -> Trace {
    TraceSpec::tiny(ports, coflows)
        .seed(seed)
        .with_deadlines(DeadlineModel { tightness, spread: 0.5, coverage: 0.8 })
        .generate()
}

/// Property 1: every coflow the controller admitted (and whose certificate
/// therefore held for its whole life) finishes by its deadline.
#[test]
fn admitted_coflows_never_expire_single_coordinator() {
    let cfg = SchedulerConfig::default();
    for seed in [1u64, 2, 3, 4, 5] {
        let trace = slo_trace(10, 16, 3.0, seed);
        let mut sched = DcoflowScheduler::new();
        let res = Simulation::run_with(&trace, &mut sched, &cfg, &sim_cfg());
        let mut admitted = 0;
        let mut rejected = 0;
        for (cid, c) in trace.coflows.iter().enumerate() {
            let Some(d) = c.deadline else { continue };
            match sched.status_of(cid) {
                AdmissionState::Admitted => {
                    admitted += 1;
                    let finished = c.arrival + res.ccts[cid];
                    assert!(
                        finished <= d + 1e-6,
                        "seed {seed}: admitted coflow {cid} missed its deadline \
                         (finished {finished:.4} > {d:.4})"
                    );
                }
                AdmissionState::Expired => {
                    panic!("seed {seed}: admitted coflow {cid} expired")
                }
                AdmissionState::Rejected => rejected += 1,
                s => panic!("seed {seed}: deadline coflow {cid} in state {s:?}"),
            }
        }
        // counters line up with the per-coflow verdicts
        assert_eq!(res.deadline.expired, 0, "seed {seed}");
        assert_eq!(res.deadline.admitted, admitted, "seed {seed}");
        assert_eq!(res.deadline.rejected, rejected, "seed {seed}");
        assert_eq!(
            (admitted + rejected) as usize,
            res.deadline.with_deadline,
            "seed {seed}: every deadline coflow gets exactly one verdict"
        );
        // met ratio covers at least the admitted set
        assert!(res.deadline.met as u64 >= admitted, "seed {seed}");
        // all coflows (incl. rejected, at background priority) finish
        assert!(res.ccts.iter().all(|c| c.is_finite() && *c > 0.0), "seed {seed}");
    }
}

/// Property 1 under the K=2 cluster: independent per-shard admission over
/// leased capacity (plus migration detach/attach) must still produce zero
/// expiries on a workload with SLO headroom, and every coflow finishes.
#[test]
fn admitted_coflows_never_expire_two_coordinators() {
    let cfg = SchedulerConfig::default();
    for seed in [1u64, 2, 3] {
        let trace = TraceSpec::tiny(12, 20)
            .with_load_factor(0.5) // halve load: leases keep ample headroom
            .seed(seed)
            .with_deadlines(DeadlineModel { tightness: 6.0, spread: 0.5, coverage: 0.8 })
            .generate();
        let cluster_cfg = SimConfig { coordinators: 2, ..sim_cfg() };
        let res = Simulation::run_cluster(&trace, SchedulerKind::Dcoflow, &cfg, &cluster_cfg);
        assert_eq!(
            res.deadline.expired, 0,
            "seed {seed}: an admitted coflow expired under K=2"
        );
        assert!(
            res.deadline.admitted >= res.deadline.met as u64 / 2,
            "seed {seed}: admission collapsed ({} admitted, {} met)",
            res.deadline.admitted,
            res.deadline.met
        );
        assert!(res.ccts.iter().all(|c| c.is_finite() && *c > 0.0), "seed {seed}");
    }
}

/// Property 2, deterministic scenario: B is rejected (A's reservation
/// saturates the shared uplink); dropping B from the schedule entirely
/// must not move A's or C's completion by a single bit.
#[test]
fn rejected_coflow_never_blocks_admitted_deterministic() {
    let records = vec![
        // A: 125 MB over (0→1), deadline 1.2 s → reserves ~0.83 Gbps
        TraceRecord::uniform(1, 0.0, vec![0], vec![1], 125.0).with_deadline(1.2),
        // B: same pair, needs ~0.84 Gbps by 1.5 s → rejected
        TraceRecord::uniform(2, 0.01, vec![0], vec![1], 125.0).with_deadline(1.5),
        // C: disjoint pair, loose deadline → admitted
        TraceRecord::uniform(3, 0.02, vec![2], vec![3], 125.0).with_deadline(5.0),
    ];
    let trace = Trace::from_records(4, records);
    let cfg = SchedulerConfig::default();

    let mut bg = DcoflowScheduler::new();
    let with_bg = Simulation::run_with(&trace, &mut bg, &cfg, &sim_cfg());
    let mut hard = DcoflowScheduler::new().without_background();
    let without_bg = Simulation::run_with(&trace, &mut hard, &cfg, &sim_cfg());

    assert_eq!(bg.status_of(0), AdmissionState::Admitted);
    assert_eq!(bg.status_of(1), AdmissionState::Rejected);
    assert_eq!(bg.status_of(2), AdmissionState::Admitted);
    // both runs must agree on the verdicts
    for cid in 0..3 {
        assert_eq!(bg.status_of(cid), hard.status_of(cid), "coflow {cid}");
    }

    // admitted coflows: identical to the bit with and without B running
    for cid in [0usize, 2] {
        assert_eq!(
            with_bg.ccts[cid].to_bits(),
            without_bg.ccts[cid].to_bits(),
            "coflow {cid} perturbed by the rejected coflow"
        );
        let c = &trace.coflows[cid];
        assert!(c.arrival + with_bg.ccts[cid] <= c.deadline.unwrap() + 1e-6);
    }
    // with the background lane, B still completes (work conservation):
    // A finishes its 1 s of work, then B runs 0.01→... and misses 1.5 s
    assert!(with_bg.ccts[1].is_finite());
    assert!(
        trace.coflows[1].arrival + with_bg.ccts[1] > 1.5,
        "B should miss its deadline from the background lane"
    );
    // without the background lane, B never runs at all
    assert!(without_bg.ccts[1].is_nan());
    assert_eq!(with_bg.deadline.met, 2);
    assert_eq!(with_bg.deadline.missed, 1);
    // A exactly: 125 MB at 1 Gbps = 1 s
    assert!((with_bg.ccts[0] - 125.0 * MB / GBPS).abs() < 1e-6);
}

/// Property 2, randomized: whenever a seeded SLO workload produces zero
/// expiries, dropping every rejected coflow from the plan leaves all
/// admitted/best-effort CCTs bit-identical (expiry-free guard: an expiry's
/// *detection time* depends on background-completion events, so histories
/// with expiries are legitimately allowed to differ).
#[test]
fn rejected_coflows_never_block_admitted_randomized() {
    let cfg = SchedulerConfig::default();
    let mut compared = 0;
    for seed in [1u64, 2, 3, 4, 5, 6] {
        let trace = slo_trace(8, 14, 2.0, seed);
        let mut bg = DcoflowScheduler::new();
        let with_bg = Simulation::run_with(&trace, &mut bg, &cfg, &sim_cfg());
        if with_bg.deadline.expired > 0 {
            continue;
        }
        let mut hard = DcoflowScheduler::new().without_background();
        let without_bg = Simulation::run_with(&trace, &mut hard, &cfg, &sim_cfg());
        for cid in 0..trace.coflows.len() {
            let status = bg.status_of(cid);
            assert_eq!(status, hard.status_of(cid), "seed {seed}: verdicts diverged");
            if matches!(status, AdmissionState::Admitted | AdmissionState::BestEffort) {
                assert_eq!(
                    with_bg.ccts[cid].to_bits(),
                    without_bg.ccts[cid].to_bits(),
                    "seed {seed}: coflow {cid} perturbed by background traffic"
                );
                compared += 1;
            }
        }
    }
    assert!(compared > 0, "no expiry-free seed produced comparable runs");
}

/// The certificate itself: later admissions can never steal an earlier
/// coflow's reserved share — the controller turns them away instead.
#[test]
fn later_admissions_cannot_steal_reserved_share() {
    let records = vec![
        // reserves 100 MB / 1 s = 0.8 of the uplink
        TraceRecord::uniform(1, 0.0, vec![0], vec![1], 100.0).with_deadline(1.0),
        // wants 100 MB / 2 s = 0.4 more → 1.2 > capacity → rejected
        TraceRecord::uniform(2, 0.0, vec![0], vec![2], 100.0).with_deadline(2.0),
        // wants 100 MB / 4.75 s ≈ 0.17 → fits in the leftover → admitted
        TraceRecord::uniform(3, 0.25, vec![0], vec![3], 100.0).with_deadline(5.0),
    ];
    let trace = Trace::from_records(4, records);
    let cfg = SchedulerConfig::default();
    let mut sched = DcoflowScheduler::new();
    let res = Simulation::run_with(&trace, &mut sched, &cfg, &sim_cfg());
    assert_eq!(sched.status_of(0), AdmissionState::Admitted);
    assert_eq!(sched.status_of(1), AdmissionState::Rejected);
    assert_eq!(sched.status_of(2), AdmissionState::Admitted);
    // both admitted coflows meet their deadlines
    for cid in [0usize, 2] {
        let c = &trace.coflows[cid];
        assert!(c.arrival + res.ccts[cid] <= c.deadline.unwrap() + 1e-6, "coflow {cid}");
    }
    assert_eq!(res.deadline.expired, 0);
}
