//! Property test of the sharded allocation pipeline: for randomized
//! fabrics (heterogeneous, including dead ports), flow/coflow layouts, and
//! plans (lane filters, bandwidth groups, duplicate entries), allocation
//! under `S ∈ {1, 2, 4, 8}` shards must be **bit-identical** to the serial
//! allocator — grants, visited count, and the stamped grant-table queries —
//! and stay bit-identical across scratch reuse. Both sharded execution
//! backends are covered: the persistent worker pool (the default: parked
//! threads woken per call) and the spawn-per-call `thread::scope`
//! baseline, plus one scratch driven through changing shard counts and
//! the restore-heavy simulator path that reuses its scratch (and thus its
//! pool) across scheduler rebuilds.

use philae::coflow::{CoflowState, FlowState};
use philae::coordinator::rate::{self, AllocScratch, FlowFilter, OrderEntry, Plan};
use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::fabric::Fabric;
use philae::sim::{SimConfig, Simulation};
use philae::trace::TraceSpec;
use philae::util::{prop, Rng};

struct Case {
    fabric: Fabric,
    flows: Vec<FlowState>,
    coflows: Vec<CoflowState>,
    plan: Plan,
}

fn random_case(rng: &mut Rng) -> Case {
    let nports = rng.range_inclusive(2, 24);
    let cap = |rng: &mut Rng| {
        if rng.chance(0.1) {
            0.0 // dead direction
        } else {
            rng.uniform(10.0, 1000.0)
        }
    };
    let ups: Vec<f64> = (0..nports).map(|_| cap(rng)).collect();
    let downs: Vec<f64> = (0..nports).map(|_| cap(rng)).collect();
    let fabric = Fabric::heterogeneous(ups, downs);

    let ncoflows = rng.range_inclusive(1, 10);
    let mut flows: Vec<FlowState> = Vec::new();
    let mut coflows: Vec<CoflowState> = Vec::new();
    for cid in 0..ncoflows {
        let nf = rng.range_inclusive(1, 30);
        let mut ids = Vec::with_capacity(nf);
        let mut total = 0.0;
        for _ in 0..nf {
            let fid = flows.len();
            let src = rng.below(nports);
            let dst = rng.below(nports);
            let size = rng.uniform(1.0, 500.0);
            let mut f = FlowState::new(fid, cid, src, dst, size);
            f.pilot = rng.chance(0.2);
            if rng.chance(0.15) {
                f.sent = size; // already finished
            }
            flows.push(f);
            ids.push(fid);
            total += size;
        }
        coflows.push(CoflowState::new(cid, 0.0, ids, total, cid as u64));
    }

    // Random priority order over the coflows, occasionally with duplicate
    // entries (exercises the cross-pass duplicate-grant merge).
    let mut order: Vec<usize> = (0..ncoflows).collect();
    for i in (1..order.len()).rev() {
        let j = rng.below(i + 1);
        order.swap(i, j);
    }
    let grouped = rng.chance(0.5);
    let ngroups = if grouped { rng.range_inclusive(1, 3) } else { 0 };
    let mut plan = Plan::default();
    if grouped {
        plan.group_weights = (0..ngroups).map(|_| rng.uniform(0.5, 4.0)).collect();
    }
    for &cid in &order {
        let filter = match rng.below(4) {
            0 => FlowFilter::PilotsOnly,
            1 => FlowFilter::NonPilots,
            _ => FlowFilter::All,
        };
        let group = if grouped && rng.chance(0.7) { Some(rng.below(ngroups)) } else { None };
        plan.entries.push(OrderEntry { coflow: cid, filter, group });
        if rng.chance(0.15) {
            // duplicate entry for the same coflow, different lane
            plan.entries.push(OrderEntry { coflow: cid, filter: FlowFilter::All, group });
        }
    }
    Case { fabric, flows, coflows, plan }
}

#[test]
fn sharded_allocation_bit_identical_to_serial() {
    prop::for_all(96, |rng| {
        let case = random_case(rng);
        let mut serial = AllocScratch::new();
        rate::allocate_into(&case.fabric, &case.flows, &case.coflows, &case.plan, &mut serial);

        // spawn=false: persistent worker pool; spawn=true: thread::scope
        for spawn in [false, true] {
            for shards in [1usize, 2, 4, 8] {
                let mut sharded = AllocScratch::new();
                sharded.set_shards(shards);
                sharded.set_spawn_workers(spawn);
                // two rounds: table/pool reuse must not perturb the result
                for round in 0..2 {
                    rate::allocate_into(
                        &case.fabric,
                        &case.flows,
                        &case.coflows,
                        &case.plan,
                        &mut sharded,
                    );
                    assert_eq!(
                        sharded.grants().len(),
                        serial.grants().len(),
                        "S={shards} spawn={spawn} round {round}: grant count"
                    );
                    for (a, b) in sharded.grants().iter().zip(serial.grants()) {
                        assert_eq!(a.0, b.0, "S={shards} spawn={spawn} round {round}: flow order");
                        assert_eq!(
                            a.1.to_bits(),
                            b.1.to_bits(),
                            "S={shards} spawn={spawn} round {round}: rate bits of flow {}",
                            a.0
                        );
                    }
                    assert_eq!(
                        sharded.visited(),
                        serial.visited(),
                        "S={shards} spawn={spawn} round {round}: visited"
                    );
                    for f in 0..case.flows.len() {
                        assert_eq!(
                            sharded.was_granted(f),
                            serial.was_granted(f),
                            "S={shards} spawn={spawn}: was_granted({f})"
                        );
                        assert_eq!(
                            sharded.granted_rate(f).to_bits(),
                            serial.granted_rate(f).to_bits(),
                            "S={shards} spawn={spawn}: granted_rate({f})"
                        );
                    }
                }
            }
        }
    });
}

/// One scratch — and therefore one worker pool — driven through changing
/// shard counts and fresh random cases must keep matching serial bit for
/// bit. The pool grows lazily (S=8 after S=2), idles surplus workers
/// (S=1 after S=8), and its per-worker emit buffers carry stale content
/// between calls; none of that may leak into the result.
#[test]
fn pooled_scratch_reused_across_shard_counts_stays_bit_identical() {
    // not prop::for_all: the whole point is ONE long-lived scratch
    // carried across cases, which an unwind-safe closure cannot capture
    let mut rng = Rng::seed_from_u64(0x9001_5EED);
    let mut reused = AllocScratch::new();
    let mut serial = AllocScratch::new();
    for case_no in 0..48usize {
        let case = random_case(&mut rng);
        rate::allocate_into(&case.fabric, &case.flows, &case.coflows, &case.plan, &mut serial);
        let shards = [2usize, 8, 3, 1, 4][case_no % 5];
        reused.set_shards(shards);
        rate::allocate_into(&case.fabric, &case.flows, &case.coflows, &case.plan, &mut reused);
        assert_eq!(
            reused.grants().len(),
            serial.grants().len(),
            "case {case_no} S={shards}: grant count after reuse"
        );
        for (a, b) in reused.grants().iter().zip(serial.grants()) {
            assert_eq!(a.0, b.0, "case {case_no} S={shards}: flow order after reuse");
            assert_eq!(
                a.1.to_bits(),
                b.1.to_bits(),
                "case {case_no} S={shards}: rate bits of flow {} after reuse",
                a.0
            );
        }
    }
}

/// The restore-heavy simulator path (`RestoringCoord`) checkpoints and
/// rebuilds the scheduler every few events while keeping its
/// `AllocScratch` — so the persistent worker pool must survive scheduler
/// restores and keep producing the exact CCTs of an uninterrupted serial
/// run.
#[test]
fn pool_survives_scheduler_restores() {
    let trace = TraceSpec::tiny(10, 16).seed(42).generate();
    let cfg = SchedulerConfig::default();
    let baseline = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
    let sim_cfg = SimConfig { alloc_shards: 4, ..SimConfig::default() };
    let (restored, restores) =
        Simulation::run_with_restore(&trace, SchedulerKind::Philae, &cfg, &sim_cfg, 3);
    assert!(restores > 0, "restore cadence too coarse for this trace");
    assert_eq!(baseline.ccts.len(), restored.ccts.len());
    for (cid, (a, b)) in baseline.ccts.iter().zip(&restored.ccts).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "coflow {cid}: CCT diverged across restores with pooled shards"
        );
    }
}

#[test]
fn sharded_allocation_never_oversubscribes_ports() {
    prop::for_all(48, |rng| {
        let case = random_case(rng);
        let mut scratch = AllocScratch::new();
        scratch.set_shards(4);
        rate::allocate_into(&case.fabric, &case.flows, &case.coflows, &case.plan, &mut scratch);
        let mut up = vec![0.0f64; case.fabric.num_ports];
        let mut down = vec![0.0f64; case.fabric.num_ports];
        for &(fid, r) in scratch.grants() {
            assert!(r > 0.0, "non-positive grant for flow {fid}");
            assert!(!case.flows[fid].done(), "grant to a finished flow {fid}");
            up[case.flows[fid].src] += r;
            down[case.flows[fid].dst] += r;
        }
        for p in 0..case.fabric.num_ports {
            assert!(
                up[p] <= case.fabric.up_capacity[p] + 1e-6,
                "uplink {p} oversubscribed: {} > {}",
                up[p],
                case.fabric.up_capacity[p]
            );
            assert!(
                down[p] <= case.fabric.down_capacity[p] + 1e-6,
                "downlink {p} oversubscribed: {} > {}",
                down[p],
                case.fabric.down_capacity[p]
            );
        }
    });
}
