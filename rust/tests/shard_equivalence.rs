//! Property test of the sharded allocation pipeline: for randomized
//! fabrics (heterogeneous, including dead ports), flow/coflow layouts, and
//! plans (lane filters, bandwidth groups, duplicate entries), allocation
//! under `S ∈ {1, 2, 4, 8}` shards must be **bit-identical** to the serial
//! allocator — grants, visited count, and the stamped grant-table queries —
//! and stay bit-identical across scratch reuse.

use philae::coflow::{CoflowState, FlowState};
use philae::coordinator::rate::{self, AllocScratch, FlowFilter, OrderEntry, Plan};
use philae::fabric::Fabric;
use philae::util::{prop, Rng};

struct Case {
    fabric: Fabric,
    flows: Vec<FlowState>,
    coflows: Vec<CoflowState>,
    plan: Plan,
}

fn random_case(rng: &mut Rng) -> Case {
    let nports = rng.range_inclusive(2, 24);
    let cap = |rng: &mut Rng| {
        if rng.chance(0.1) {
            0.0 // dead direction
        } else {
            rng.uniform(10.0, 1000.0)
        }
    };
    let ups: Vec<f64> = (0..nports).map(|_| cap(rng)).collect();
    let downs: Vec<f64> = (0..nports).map(|_| cap(rng)).collect();
    let fabric = Fabric::heterogeneous(ups, downs);

    let ncoflows = rng.range_inclusive(1, 10);
    let mut flows: Vec<FlowState> = Vec::new();
    let mut coflows: Vec<CoflowState> = Vec::new();
    for cid in 0..ncoflows {
        let nf = rng.range_inclusive(1, 30);
        let mut ids = Vec::with_capacity(nf);
        let mut total = 0.0;
        for _ in 0..nf {
            let fid = flows.len();
            let src = rng.below(nports);
            let dst = rng.below(nports);
            let size = rng.uniform(1.0, 500.0);
            let mut f = FlowState::new(fid, cid, src, dst, size);
            f.pilot = rng.chance(0.2);
            if rng.chance(0.15) {
                f.sent = size; // already finished
            }
            flows.push(f);
            ids.push(fid);
            total += size;
        }
        coflows.push(CoflowState::new(cid, 0.0, ids, total, cid as u64));
    }

    // Random priority order over the coflows, occasionally with duplicate
    // entries (exercises the cross-pass duplicate-grant merge).
    let mut order: Vec<usize> = (0..ncoflows).collect();
    for i in (1..order.len()).rev() {
        let j = rng.below(i + 1);
        order.swap(i, j);
    }
    let grouped = rng.chance(0.5);
    let ngroups = if grouped { rng.range_inclusive(1, 3) } else { 0 };
    let mut plan = Plan::default();
    if grouped {
        plan.group_weights = (0..ngroups).map(|_| rng.uniform(0.5, 4.0)).collect();
    }
    for &cid in &order {
        let filter = match rng.below(4) {
            0 => FlowFilter::PilotsOnly,
            1 => FlowFilter::NonPilots,
            _ => FlowFilter::All,
        };
        let group = if grouped && rng.chance(0.7) { Some(rng.below(ngroups)) } else { None };
        plan.entries.push(OrderEntry { coflow: cid, filter, group });
        if rng.chance(0.15) {
            // duplicate entry for the same coflow, different lane
            plan.entries.push(OrderEntry { coflow: cid, filter: FlowFilter::All, group });
        }
    }
    Case { fabric, flows, coflows, plan }
}

#[test]
fn sharded_allocation_bit_identical_to_serial() {
    prop::for_all(96, |rng| {
        let case = random_case(rng);
        let mut serial = AllocScratch::new();
        rate::allocate_into(&case.fabric, &case.flows, &case.coflows, &case.plan, &mut serial);

        for shards in [1usize, 2, 4, 8] {
            let mut sharded = AllocScratch::new();
            sharded.set_shards(shards);
            // two rounds: table reuse must not perturb the result
            for round in 0..2 {
                rate::allocate_into(
                    &case.fabric,
                    &case.flows,
                    &case.coflows,
                    &case.plan,
                    &mut sharded,
                );
                assert_eq!(
                    sharded.grants().len(),
                    serial.grants().len(),
                    "S={shards} round {round}: grant count"
                );
                for (a, b) in sharded.grants().iter().zip(serial.grants()) {
                    assert_eq!(a.0, b.0, "S={shards} round {round}: flow order");
                    assert_eq!(
                        a.1.to_bits(),
                        b.1.to_bits(),
                        "S={shards} round {round}: rate bits of flow {}",
                        a.0
                    );
                }
                assert_eq!(
                    sharded.visited(),
                    serial.visited(),
                    "S={shards} round {round}: visited"
                );
                for f in 0..case.flows.len() {
                    assert_eq!(
                        sharded.was_granted(f),
                        serial.was_granted(f),
                        "S={shards}: was_granted({f})"
                    );
                    assert_eq!(
                        sharded.granted_rate(f).to_bits(),
                        serial.granted_rate(f).to_bits(),
                        "S={shards}: granted_rate({f})"
                    );
                }
            }
        }
    });
}

#[test]
fn sharded_allocation_never_oversubscribes_ports() {
    prop::for_all(48, |rng| {
        let case = random_case(rng);
        let mut scratch = AllocScratch::new();
        scratch.set_shards(4);
        rate::allocate_into(&case.fabric, &case.flows, &case.coflows, &case.plan, &mut scratch);
        let mut up = vec![0.0f64; case.fabric.num_ports];
        let mut down = vec![0.0f64; case.fabric.num_ports];
        for &(fid, r) in scratch.grants() {
            assert!(r > 0.0, "non-positive grant for flow {fid}");
            assert!(!case.flows[fid].done(), "grant to a finished flow {fid}");
            up[case.flows[fid].src] += r;
            down[case.flows[fid].dst] += r;
        }
        for p in 0..case.fabric.num_ports {
            assert!(
                up[p] <= case.fabric.up_capacity[p] + 1e-6,
                "uplink {p} oversubscribed: {} > {}",
                up[p],
                case.fabric.up_capacity[p]
            );
            assert!(
                down[p] <= case.fabric.down_capacity[p] + 1e-6,
                "downlink {p} oversubscribed: {} > {}",
                down[p],
                case.fabric.down_capacity[p]
            );
        }
    });
}
