//! Live-service integration: the threaded coordinator + per-port agents run
//! a small trace end to end, coflow ops (register/deregister/update) behave,
//! and the measured interval accounting is sane.

use philae::coordinator::SchedulerKind;
use philae::service::{run_service, run_soak, ServiceConfig};
use philae::trace::{DeadlineModel, TraceSpec};

fn svc(kind: SchedulerKind) -> ServiceConfig {
    // `..default()` keeps `alloc_shards` on `rate::env_test_shards()`, so
    // the PHILAE_TEST_SHARDS CI leg drives the live service through the
    // sharded allocator too.
    ServiceConfig {
        kind,
        time_scale: 200.0, // fast replay: tiny traces finish in < 2 s wall
        ..ServiceConfig::default()
    }
}

#[test]
fn multi_coordinator_service_completes_trace() {
    // K = 2 coordinator shards with leased capacity: every coflow must
    // still finish, for both the event-triggered (Philae) and the
    // periodic (Aalo) pipelines.
    for kind in [SchedulerKind::Philae, SchedulerKind::Aalo] {
        let trace = TraceSpec::tiny(8, 14).seed(21).generate();
        let cfg = ServiceConfig { coordinators: 2, ..svc(kind) };
        let report = run_service(&trace, &cfg).expect("sharded service run");
        assert_eq!(report.ccts.len(), trace.coflows.len());
        for (i, &cct) in report.ccts.iter().enumerate() {
            assert!(
                cct.is_finite() && cct > 0.0,
                "{kind:?} K=2: coflow {i} unfinished: {cct}"
            );
        }
        assert!(report.rate_calcs > 0);
    }
}

#[test]
fn philae_service_completes_trace() {
    let trace = TraceSpec::tiny(8, 12).seed(5).generate();
    let report = run_service(&trace, &svc(SchedulerKind::Philae)).expect("service run");
    assert_eq!(report.ccts.len(), trace.coflows.len());
    for (i, &cct) in report.ccts.iter().enumerate() {
        assert!(cct.is_finite() && cct > 0.0, "coflow {i} unfinished: {cct}");
    }
    assert!(report.rate_calcs > 0);
    assert!(report.update_msgs as usize >= trace.flows.len());
    assert!(!report.used_engine);
    // event-loop runtime accounting: no checkpoint dir, so nothing
    // restored; latency percentiles sampled and ordered
    assert_eq!(report.restored_shards, 0);
    assert!(report.realloc_p50 >= 0.0);
    assert!(
        report.realloc_p99 >= report.realloc_p50,
        "p99 {} below p50 {}",
        report.realloc_p99,
        report.realloc_p50
    );
}

#[test]
fn aalo_service_completes_and_reports_intervals() {
    let trace = TraceSpec::tiny(8, 10).seed(6).generate();
    let report = run_service(&trace, &svc(SchedulerKind::Aalo)).expect("service run");
    assert!(report.ccts.iter().all(|c| c.is_finite() && *c > 0.0));
    assert!(report.intervals.intervals > 0, "no busy intervals recorded");
    // Aalo gets byte updates on top of completions
    assert!(report.update_msgs as usize > trace.flows.len());
}

#[test]
fn full_scheduler_registry_completes_trace() {
    // the serve surface accepts every registry kind, not just philae/aalo
    for kind in [
        SchedulerKind::Sebf,
        SchedulerKind::Scf,
        SchedulerKind::Fifo,
        SchedulerKind::Saath,
        SchedulerKind::Dcoflow,
    ] {
        let trace = TraceSpec::tiny(6, 8).seed(13).generate();
        let report = run_service(&trace, &svc(kind)).expect("service run");
        assert_eq!(report.scheduler, kind.build(&trace, &Default::default()).name());
        for (i, &cct) in report.ccts.iter().enumerate() {
            assert!(
                cct.is_finite() && cct > 0.0,
                "{kind:?}: coflow {i} unfinished: {cct}"
            );
        }
        assert!(report.rate_calcs > 0, "{kind:?}");
    }
}

#[test]
fn dcoflow_service_reports_slo_accounting() {
    // loose SLOs on a small trace: every coflow carries a deadline, the
    // admission controller sees them all, and nothing expires
    let trace = TraceSpec::tiny(6, 8)
        .seed(14)
        .with_deadlines(DeadlineModel { tightness: 50.0, spread: 0.5, coverage: 1.0 })
        .generate();
    let report = run_service(&trace, &svc(SchedulerKind::Dcoflow)).expect("service run");
    assert_eq!(report.deadline.with_deadline, trace.coflows.len());
    assert_eq!(
        report.deadline.admitted + report.deadline.rejected,
        trace.coflows.len() as u64,
        "every deadline coflow gets a verdict"
    );
    assert!(report.ccts.iter().all(|c| c.is_finite() && *c > 0.0));
}

#[test]
fn philae_sends_fewer_updates_than_aalo() {
    let trace = TraceSpec::tiny(10, 15).seed(7).generate();
    let ph = run_service(&trace, &svc(SchedulerKind::Philae)).expect("philae");
    let aa = run_service(&trace, &svc(SchedulerKind::Aalo)).expect("aalo");
    assert!(
        aa.update_msgs > ph.update_msgs,
        "aalo {} should exceed philae {}",
        aa.update_msgs,
        ph.update_msgs
    );
}

#[test]
fn service_restores_checkpoints_from_disk_on_start() {
    // run 1 persists sealed shard checkpoints; a fresh incarnation pointed
    // at the same directory must consume them before accepting input.
    // Philae exercises the seal-validation restore, Aalo the generic
    // import_state path.
    for kind in [SchedulerKind::Philae, SchedulerKind::Aalo] {
        let dir = std::env::temp_dir()
            .join(format!("philae_smoke_restore_{}_{kind:?}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let trace = TraceSpec::tiny(8, 12).seed(31).generate();
        let cfg = ServiceConfig {
            checkpoint_every: 1,
            checkpoint_dir: Some(dir.clone()),
            ..svc(kind)
        };
        let first = run_service(&trace, &cfg).expect("first incarnation");
        assert!(first.ccts.iter().all(|c| c.is_finite() && *c > 0.0), "{kind:?}: run 1");
        assert!(first.checkpoints_written > 0, "{kind:?}: no checkpoints persisted");
        assert_eq!(first.restored_shards, 0, "{kind:?}: run 1 started from a clean dir");
        assert!(dir.join("shard_0.ckpt").exists(), "{kind:?}: shard_0.ckpt missing");

        let second = run_service(&trace, &cfg).expect("second incarnation");
        assert!(second.restored_shards >= 1, "{kind:?}: on-disk checkpoint not consumed");
        assert!(
            second.ccts.iter().all(|c| c.is_finite() && *c > 0.0),
            "{kind:?}: restored service left coflows unfinished"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn auto_watchdog_stays_quiet_on_healthy_run() {
    // cadence-derived miss thresholds must never age out agents that are
    // merely slow — a healthy run completes with zero masked ports
    let trace = TraceSpec::tiny(8, 12).seed(9).generate();
    let cfg = ServiceConfig { agent_miss_auto: true, ..svc(SchedulerKind::Philae) };
    let report = run_service(&trace, &cfg).expect("auto-watchdog run");
    assert!(report.ccts.iter().all(|c| c.is_finite() && *c > 0.0));
    assert_eq!(report.ports_aged_out, 0, "healthy agents were aged out");
    assert_eq!(report.ports_restored, 0);
}

#[test]
fn service_with_engine_if_artifacts_present() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping engine service test: artifacts missing");
        return;
    }
    let trace = TraceSpec::tiny(6, 8).seed(8).generate();
    let mut cfg = svc(SchedulerKind::Philae);
    cfg.engine_dir = Some("artifacts".into());
    let report = run_service(&trace, &cfg).expect("engine service run");
    assert!(report.used_engine);
    assert!(report.ccts.iter().all(|c| c.is_finite() && *c > 0.0));
}

#[test]
fn service_obs_plane_records_lifecycle_and_metrics() {
    let trace = TraceSpec::tiny(8, 12).seed(5).generate();

    // obs off (the default): the report carries no snapshot
    let off = run_service(&trace, &svc(SchedulerKind::Philae)).expect("obs-off run");
    assert!(off.obs.is_none(), "obs defaults to disabled");
    // …but the realloc histogram is always on and ordered
    assert!(off.realloc_p999 >= off.realloc_p99);
    assert!(off.realloc_p99 >= off.realloc_p50);

    // obs on: lifecycle events + service gauges/counters survive to the report
    let cfg = ServiceConfig { obs_events: 1 << 14, coordinators: 2, ..svc(SchedulerKind::Philae) };
    let report = run_service(&trace, &cfg).expect("obs-on run");
    assert!(report.ccts.iter().all(|c| c.is_finite() && *c > 0.0));
    let snap = report.obs.as_ref().expect("obs snapshot in report");

    use philae::obs::EventKind;
    let count = |k: EventKind| snap.events.iter().filter(|e| e.kind == k).count();
    assert_eq!(count(EventKind::Arrival), trace.coflows.len(), "one Arrival per coflow");
    assert_eq!(
        count(EventKind::CoflowComplete),
        trace.coflows.len(),
        "one CoflowComplete per coflow"
    );
    assert_eq!(count(EventKind::FlowComplete), trace.flows.len(), "one FlowComplete per flow");

    // wall-clock stamps are live (unlike pure simulation's zeros)
    assert!(snap.events.iter().any(|e| e.wall_ns > 0), "service events carry wall time");

    // registry: the realloc histogram mirrors every reallocation, and the
    // K=2 run published a lease-utilization gauge per shard
    let h = snap.registry.hist_named("svc.realloc_ns").expect("svc.realloc_ns");
    assert_eq!(h.count(), report.rate_calcs, "histogram sees every reallocation");
    assert!(snap.registry.gauge_value("svc.lease_util.0").is_some());
    assert!(snap.registry.gauge_value("svc.lease_util.1").is_some());
    assert!(snap.registry.gauge_value("svc.input_queue_depth").is_some());
}

#[test]
fn soak_registration_rides_the_buffer_pool() {
    // run_soak's feeder awaits each registration reply and the coordinator
    // boomerangs the consumed record before replying — so from the second
    // registration on, every record buffer must come from the pool.
    let trace = TraceSpec::tiny(8, 12).seed(5).generate();
    let report = run_soak(&trace, &svc(SchedulerKind::Philae)).expect("soak run");
    assert!(report.ccts.iter().all(|c| c.is_finite() && *c > 0.0));
    assert!(
        report.register_bufs_reused >= trace.coflows.len() as u64 - 1,
        "register path fell back to fresh buffers: {} reused of {} coflows",
        report.register_bufs_reused,
        trace.coflows.len()
    );
}
