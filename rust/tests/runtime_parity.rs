//! PJRT ↔ native parity: the AOT artifacts (L2 scorer graph composed of the
//! L1 Pallas kernels) must agree with the rust-native mirror functions the
//! simulator uses. Requires `make artifacts`; tests skip politely if the
//! artifacts are missing (CI without python).

use philae::runtime::{
    native_contention, native_estimate, native_score, BatchFeatures, Engine,
};
use philae::util::Rng;

fn engine() -> Option<Engine> {
    match Engine::load("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping runtime parity test: {err:#}");
            None
        }
    }
}

fn fill_random(batch: &mut BatchFeatures, seed: u64) -> Vec<(Vec<f64>, usize, f64, Vec<usize>)> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut rows = Vec::new();
    let live = batch.c.min(40);
    for row in 0..live {
        let m = rng.range_inclusive(1, batch.m.min(10));
        let sizes: Vec<f64> = (0..m).map(|_| rng.lognormal(15.0, 1.5)).collect();
        let nflows = rng.range_inclusive(m, 5000);
        let done = rng.uniform(0.0, 1e8);
        let width = rng.range_inclusive(1, 40);
        let half = batch.p / 2;
        let mut ports: Vec<usize> = (0..width).map(|_| rng.below(half)).collect();
        ports.extend((0..width).map(|_| half + rng.below(half)));
        ports.sort_unstable();
        ports.dedup();
        batch.set_row(row, &sizes, nflows, done, &ports, seed ^ row as u64);
        rows.push((sizes, nflows, done, ports));
    }
    rows
}

#[test]
fn estimator_matches_native_mean() {
    let Some(engine) = engine() else { return };
    let mut batch = BatchFeatures::new(&engine.manifest);
    let rows = fill_random(&mut batch, 7);
    let (est, lcb) = engine.estimate(&batch).expect("estimate");
    for (i, (sizes, nflows, _, _)) in rows.iter().enumerate() {
        let expect = native_estimate(sizes, *nflows as f64);
        let got = est[i] as f64;
        assert!(
            (got - expect).abs() <= expect.abs() * 2e-4 + 1.0,
            "row {i}: kernel est {got} vs native {expect}"
        );
        // LCB never exceeds the unbiased estimate (modulo float noise)
        assert!(lcb[i] as f64 <= expect * (1.0 + 1e-3) + 1.0);
    }
}

#[test]
fn contention_matches_native() {
    let Some(engine) = engine() else { return };
    let mut batch = BatchFeatures::new(&engine.manifest);
    fill_random(&mut batch, 21);
    let kernel = engine.contention(&batch).expect("contention");
    let native = native_contention(&batch.occ_rows());
    assert_eq!(kernel.len(), native.len());
    for (i, (k, n)) in kernel.iter().zip(native.iter()).enumerate() {
        assert!(
            (k - n).abs() <= n.abs() * 1e-4 + 1e-3,
            "row {i}: kernel {k} vs native {n}"
        );
    }
}

#[test]
fn scorer_composes_estimator_and_contention() {
    let Some(engine) = engine() else { return };
    let mut batch = BatchFeatures::new(&engine.manifest);
    let rows = fill_random(&mut batch, 35);
    let weight = 0.5f32;
    let out = engine.score(&batch, weight).expect("score");
    let native_cont = native_contention(&batch.occ_rows());
    for (i, (sizes, nflows, done, _)) in rows.iter().enumerate() {
        let est = native_estimate(sizes, *nflows as f64);
        let expect = native_score(est, *done, native_cont[i] as f64, weight as f64);
        let got = out.score[i] as f64;
        assert!(
            (got - expect).abs() <= expect.abs() * 5e-4 + 10.0,
            "row {i}: scorer {got} vs native {expect} (est {est}, cont {})",
            native_cont[i]
        );
    }
}

#[test]
fn scorer_is_deterministic_across_calls() {
    let Some(engine) = engine() else { return };
    let mut batch = BatchFeatures::new(&engine.manifest);
    fill_random(&mut batch, 99);
    let a = engine.score(&batch, 0.5).unwrap();
    let b = engine.score(&batch, 0.5).unwrap();
    assert_eq!(a, b);
}

#[test]
fn empty_batch_yields_padding_values() {
    let Some(engine) = engine() else { return };
    let mut batch = BatchFeatures::new(&engine.manifest);
    batch.set_row(0, &[], 1, 0.0, &[], 0); // a live row with no pilots
    let (est, lcb) = engine.estimate(&batch).unwrap();
    assert_eq!(est[0], 0.0);
    assert_eq!(lcb[0], 1.0); // floored LCB
}

#[test]
fn manifest_shapes_cover_scheduler_defaults() {
    let Some(engine) = engine() else { return };
    let cfg = philae::coordinator::SchedulerConfig::default();
    assert!(engine.manifest.m >= cfg.pilot_max, "M must hold pilot_max");
    assert!(engine.manifest.p >= 2 * 900, "P must hold the 900-port run");
    assert_eq!(engine.manifest.lcb_sigmas, cfg.lcb_sigmas);
}
