//! Property-based integration tests over the whole simulator: work
//! conservation, feasibility, starvation freedom, determinism — across
//! random workloads and every scheduler (the in-crate `util::prop` driver
//! stands in for proptest on this offline image).

use philae::coordinator::{rate, Scheduler, SchedulerConfig, SchedulerKind};
use philae::metrics::MessageCostModel;
use philae::sim::{world_from_trace, SimConfig, Simulation};
use philae::trace::{Trace, TraceRecord, TraceSpec};
use philae::util::{prop, Rng};
use philae::{GBPS, MB};

fn random_trace(rng: &mut Rng) -> Trace {
    let ports = rng.range_inclusive(2, 24);
    let coflows = rng.range_inclusive(1, 25);
    TraceSpec::tiny(ports, coflows)
        .seed(rng.next_u64())
        .generate()
}

#[test]
fn every_scheduler_completes_every_coflow() {
    prop::for_all(24, |rng| {
        let trace = random_trace(rng);
        let kind = SchedulerKind::all()[rng.below(SchedulerKind::all().len())];
        let res = Simulation::run(&trace, kind, &SchedulerConfig::default());
        for (i, &cct) in res.ccts.iter().enumerate() {
            assert!(
                cct.is_finite() && cct > 0.0,
                "{kind:?}: coflow {i} never finished (starvation?)"
            );
        }
    });
}

#[test]
fn allocation_never_oversubscribes_ports() {
    prop::for_all(32, |rng| {
        let trace = random_trace(rng);
        let mut world = world_from_trace(&trace);
        world.active = (0..trace.coflows.len()).collect();
        let kind = SchedulerKind::all()[rng.below(SchedulerKind::all().len())];
        let mut sched = kind.build(&trace, &SchedulerConfig::default());
        for cid in 0..trace.coflows.len() {
            sched.on_arrival(cid, &mut world);
        }
        let plan = sched.order(&world);
        let alloc = rate::allocate(&world.fabric, &world.flows, &world.coflows, &plan);
        let mut up = vec![0.0f64; trace.num_ports];
        let mut down = vec![0.0f64; trace.num_ports];
        for &(fid, r) in &alloc.grants {
            assert!(r > 0.0, "zero-rate grant");
            up[world.flows[fid].src] += r;
            down[world.flows[fid].dst] += r;
        }
        for p in 0..trace.num_ports {
            assert!(up[p] <= GBPS * (1.0 + 1e-9), "uplink {p} oversubscribed: {}", up[p]);
            assert!(down[p] <= GBPS * (1.0 + 1e-9), "downlink {p}: {}", down[p]);
        }
    });
}

#[test]
fn allocation_is_work_conserving() {
    // If any (src,dst) pair with an unfinished flow has both sides free,
    // the allocator must have granted something on that pair's bottleneck.
    prop::for_all(32, |rng| {
        let trace = random_trace(rng);
        let mut world = world_from_trace(&trace);
        world.active = (0..trace.coflows.len()).collect();
        let mut sched = SchedulerKind::Philae.build(&trace, &SchedulerConfig::default());
        for cid in 0..trace.coflows.len() {
            sched.on_arrival(cid, &mut world);
        }
        let plan = sched.order(&world);
        let alloc = rate::allocate(&world.fabric, &world.flows, &world.coflows, &plan);
        let mut up = vec![0.0f64; trace.num_ports];
        let mut down = vec![0.0f64; trace.num_ports];
        for &(fid, r) in &alloc.grants {
            up[world.flows[fid].src] += r;
            down[world.flows[fid].dst] += r;
        }
        for f in &world.flows {
            if f.done() {
                continue;
            }
            let headroom = (GBPS - up[f.src]).min(GBPS - down[f.dst]);
            assert!(
                headroom <= 1e-6,
                "flow {} could run: {} B/s free on ({}, {})",
                f.id,
                headroom,
                f.src,
                f.dst
            );
        }
    });
}

#[test]
fn simulation_is_deterministic() {
    prop::for_all(8, |rng| {
        let trace = random_trace(rng);
        let kind = SchedulerKind::all()[rng.below(SchedulerKind::all().len())];
        let mut cfg = SchedulerConfig::default();
        cfg.dynamics_seed = rng.next_u64();
        cfg.report_jitter = if rng.chance(0.5) { 0.01 } else { 0.0 };
        let a = Simulation::run(&trace, kind, &cfg);
        let b = Simulation::run(&trace, kind, &cfg);
        assert_eq!(a.ccts, b.ccts, "{kind:?} not deterministic");
        assert_eq!(a.rate_calcs, b.rate_calcs);
        assert_eq!(a.update_msgs, b.update_msgs);
    });
}

#[test]
fn total_bytes_conserved_through_simulation() {
    // Makespan on a single shared pair must equal total-bytes / rate
    // regardless of scheduler (no bytes created or lost).
    prop::for_all(16, |rng| {
        let n = rng.range_inclusive(1, 8);
        let records: Vec<TraceRecord> = (0..n)
            .map(|i| {
                TraceRecord::uniform(
                    i as u64 + 1,
                    0.0,
                    vec![0],
                    vec![1],
                    (rng.range_inclusive(1, 50)) as f64,
                )
            })
            .collect();
        let trace = Trace::from_records(2, records);
        let expected = trace.total_bytes() / GBPS;
        let kind = SchedulerKind::all()[rng.below(SchedulerKind::all().len())];
        let res = Simulation::run(&trace, kind, &SchedulerConfig::default());
        assert!(
            (res.makespan - expected).abs() < 1e-3,
            "{kind:?}: makespan {} != {}",
            res.makespan,
            expected
        );
    });
}

#[test]
fn philae_updates_are_exactly_flow_completions() {
    prop::for_all(12, |rng| {
        let trace = random_trace(rng);
        let res = Simulation::run(&trace, SchedulerKind::Philae, &SchedulerConfig::default());
        assert_eq!(res.update_msgs as usize, trace.flows.len());
    });
}

#[test]
fn aalo_demotions_are_monotone_and_updates_dwarf_philae() {
    prop::for_all(8, |rng| {
        let mut trace = random_trace(rng);
        // make at least one coflow big enough to cross E = 10 MB
        if let Some(f) = trace.flows.first().copied() {
            let _ = f;
        }
        trace = TraceSpec::tiny(8, 10).seed(rng.next_u64()).generate();
        let cfg = SchedulerConfig::default();
        let aalo = Simulation::run(&trace, SchedulerKind::Aalo, &cfg);
        let ph = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
        assert!(aalo.update_msgs > ph.update_msgs);
    });
}

#[test]
fn starvation_freedom_under_adversarial_arrivals() {
    // A huge multi-flow coflow (so it gets estimated and deprioritized by
    // SJF) with a long stream of small ones arriving on its ports: the
    // aging lane must still let it finish, and it must actually have waited.
    let mut records = vec![TraceRecord::uniform(1, 0.0, vec![0, 1], vec![0, 1], 2500.0)];
    for i in 0..400 {
        records.push(TraceRecord::uniform(
            i + 2,
            0.05 * (i as f64),
            vec![0],
            vec![1],
            2.0,
        ));
    }
    let trace = Trace::from_records(2, records);
    let mut cfg = SchedulerConfig::default();
    cfg.age_threshold = 20.0; // aggressive aging for the test
    let res = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
    assert!(res.ccts[0].is_finite(), "big coflow starved");
    // bottleneck alone = 2.5 GB / 1 Gbps = 20 s; it must have been delayed
    // by the small-coflow stream but still complete (aging guarantee)
    assert!(res.ccts[0] > 20.0 + 1.0, "cct {}", res.ccts[0]);
}

#[test]
fn jitter_and_loss_do_not_break_completion() {
    prop::for_all(12, |rng| {
        let trace = random_trace(rng);
        let mut cfg = SchedulerConfig::default();
        cfg.report_jitter = rng.uniform(0.0, 0.2);
        cfg.update_loss_prob = rng.uniform(0.0, 0.5);
        cfg.dynamics_seed = rng.next_u64();
        for kind in [SchedulerKind::Philae, SchedulerKind::Aalo] {
            let res = Simulation::run(&trace, kind, &cfg);
            assert!(res.ccts.iter().all(|c| c.is_finite() && *c > 0.0));
        }
    });
}

#[test]
fn oracle_never_loses_badly_to_fifo() {
    prop::for_all(12, |rng| {
        let trace = random_trace(rng);
        let cfg = SchedulerConfig::default();
        let fifo = Simulation::run(&trace, SchedulerKind::Fifo, &cfg);
        let sebf = Simulation::run(&trace, SchedulerKind::Sebf, &cfg);
        assert!(
            sebf.avg_cct() <= fifo.avg_cct() * 1.10 + 1e-9,
            "oracle {} vs fifo {}",
            sebf.avg_cct(),
            fifo.avg_cct()
        );
    });
}

#[test]
fn interval_accounting_consistent() {
    let trace = TraceSpec::tiny(10, 20).seed(3).generate();
    let cfg = SchedulerConfig::default();
    let sim_cfg = SimConfig {
        costs: MessageCostModel::default(),
        ..Default::default()
    };
    let mut sched = SchedulerKind::Aalo.build(&trace, &cfg);
    let res = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg);
    assert!(res.intervals.intervals > 0);
    assert!(res.intervals.missed_fraction() >= 0.0);
    assert!(res.intervals.missed_fraction() <= 1.0);
    // totals line up with per-interval means
    let approx_updates =
        res.intervals.updates_per_interval.mean() * res.intervals.intervals as f64;
    assert!(approx_updates <= res.update_msgs as f64 * 1.01 + 1.0);
}

#[test]
fn wide_only_and_replicate_compose_with_sim() {
    let trace = TraceSpec::tiny(12, 16).seed(9).generate();
    let cfg = SchedulerConfig::default();
    let wide = trace.wide_only();
    if !wide.coflows.is_empty() {
        let res = Simulation::run(&wide, SchedulerKind::Philae, &cfg);
        assert!(res.ccts.iter().all(|c| c.is_finite()));
    }
    let rep = trace.replicate(3);
    let res = Simulation::run(&rep, SchedulerKind::Philae, &cfg);
    assert_eq!(res.ccts.len(), 3 * trace.coflows.len());
    assert!(res.ccts.iter().all(|c| c.is_finite()));
}

#[test]
fn single_coflow_cct_matches_bottleneck_bound() {
    // alone in the network, CCT = bottleneck bytes / port rate
    prop::for_all(16, |rng| {
        let ports = rng.range_inclusive(2, 10);
        let nm = rng.range_inclusive(1, ports.min(4));
        let nr = rng.range_inclusive(1, ports.min(4));
        let mappers: Vec<usize> = (0..nm).collect();
        let reducers: Vec<usize> = (0..nr).map(|i| (ports - 1 - i).max(0)).collect();
        let mb = rng.range_inclusive(1, 100) as f64;
        let rec = TraceRecord::uniform(1, 0.0, mappers, reducers, mb);
        let trace = Trace::from_records(ports, vec![rec]);
        let bottleneck = trace.oracles()[0].bottleneck_bytes;
        let res = Simulation::run(&trace, SchedulerKind::Philae, &SchedulerConfig::default());
        let lower = bottleneck / GBPS;
        assert!(
            res.ccts[0] >= lower - 1e-6,
            "CCT {} below bottleneck bound {lower}",
            res.ccts[0]
        );
        // with no competition the greedy allocator should be near the bound
        assert!(
            res.ccts[0] <= lower * (1.0 + 0.5) + (trace.flows.len() as f64) * (MB / GBPS),
            "CCT {} far above bound {lower}",
            res.ccts[0]
        );
    });
}
