//! Property test for the incremental order engine: replay random
//! arrival/completion/tick sequences against every scheduler kind and
//! assert after each event that the incrementally maintained
//! `order_into` plan is identical to the from-scratch `order_full_into`
//! oracle re-sort.
//!
//! The driver mirrors exactly the world mutations the simulator engine
//! performs around each event (port occupancy through the epoch-bumping
//! `PortLoad` methods, active-list bookkeeping, byte/progress accounting),
//! so the schedulers see the same state transitions as in a real run.

use philae::coflow::CoflowPhase;
use philae::coordinator::{Plan, Scheduler, SchedulerConfig, SchedulerKind, World};
use philae::sim::world_from_trace;
use philae::trace::TraceSpec;
use philae::util::{prop, Rng};
use philae::{CoflowId, FlowId, Time};

fn check(sched: &mut dyn Scheduler, world: &World, kind: SchedulerKind, step: usize) {
    let mut inc = Plan::default();
    let mut full = Plan::default();
    sched.order_into(world, &mut inc);
    sched.order_full_into(world, &mut full);
    assert_eq!(
        inc.entries, full.entries,
        "{kind:?} step {step}: incremental order diverged from the oracle"
    );
    assert_eq!(
        inc.group_weights, full.group_weights,
        "{kind:?} step {step}: group weights diverged"
    );
}

/// Mirror of the engine's `admit`: activate the coflow and register port
/// occupancy/backlog.
fn admit(world: &mut World, cid: CoflowId) {
    world.active.push(cid);
    for i in 0..world.coflows[cid].flows.len() {
        let f = world.coflows[cid].flows[i];
        let fl = world.flows[f];
        world.load.up_bytes[fl.src] += fl.size;
        world.load.down_bytes[fl.dst] += fl.size;
    }
    for i in 0..world.coflows[cid].senders.len() {
        let p = world.coflows[cid].senders[i];
        world.load.occupy_up(p);
    }
    for i in 0..world.coflows[cid].receivers.len() {
        let p = world.coflows[cid].receivers[i];
        world.load.occupy_down(p);
    }
}

/// Mirror of the engine's `complete_flow`; returns whether the whole
/// coflow just finished.
fn complete(world: &mut World, fid: FlowId, now: Time) -> bool {
    world.now = now;
    let fl = world.flows[fid];
    let cid = fl.coflow;
    {
        let f = &mut world.flows[fid];
        f.sent = f.size;
        f.rate = 0.0;
        f.finished_at = Some(now);
    }
    world.load.up_bytes[fl.src] = (world.load.up_bytes[fl.src] - fl.size).max(0.0);
    world.load.down_bytes[fl.dst] = (world.load.down_bytes[fl.dst] - fl.size).max(0.0);
    // progress accounting feeds the Aalo/Saath/SCF/SEBF keys
    world.coflows[cid].bytes_sent += fl.size;
    // port freeing: last unfinished flow of this coflow at each endpoint
    let freed_up = !world.coflows[cid].flows.iter().any(|&g| {
        let w = world.flows[g];
        w.src == fl.src && w.finished_at.is_none()
    });
    let freed_down = !world.coflows[cid].flows.iter().any(|&g| {
        let w = world.flows[g];
        w.dst == fl.dst && w.finished_at.is_none()
    });
    if freed_up {
        world.load.release_up(fl.src);
    }
    if freed_down {
        world.load.release_down(fl.dst);
    }
    // O(1) removal from the allocator iteration set
    let pos = world.flows[fid].active_pos;
    let c = &mut world.coflows[cid];
    if pos < c.active_list.len() && c.active_list[pos] == fid {
        c.active_list.swap_remove(pos);
        if pos < c.active_list.len() {
            let moved = c.active_list[pos];
            world.flows[moved].active_pos = pos;
        }
    }
    let c = &mut world.coflows[cid];
    c.active_flows -= 1;
    if fl.size > c.max_finished_flow {
        c.max_finished_flow = fl.size;
    }
    if c.active_flows == 0 && c.finished_at.is_none() {
        c.finished_at = Some(now);
        c.phase = CoflowPhase::Done;
        world.active.retain(|&x| x != cid);
        true
    } else {
        false
    }
}

/// Driver shape: trace geometry plus event-mix knobs.
struct DriveOpts {
    /// Inclusive port-count range for the generated trace.
    ports: (usize, usize),
    /// Inclusive coflow-count range.
    coflows: (usize, usize),
    /// Probability of preferring an arrival when both event types are
    /// possible.
    arrival_p: f64,
    /// Probability of running a case with an aggressive age threshold so
    /// the express lane is exercised.
    aging_p: f64,
}

fn drive(kind: SchedulerKind, rng: &mut Rng, opts: &DriveOpts) {
    let ports = rng.range_inclusive(opts.ports.0, opts.ports.1);
    let n = rng.range_inclusive(opts.coflows.0, opts.coflows.1);
    let trace = TraceSpec::tiny(ports, n).seed(rng.next_u64()).generate();
    let mut world = world_from_trace(&trace);
    let mut cfg = SchedulerConfig::default();
    if rng.chance(opts.aging_p) {
        cfg.age_threshold = 0.02;
    }
    let mut sched = kind.build(&trace, &cfg);

    let mut arrivals: Vec<(Time, CoflowId)> =
        trace.coflows.iter().map(|c| (c.arrival, c.id)).collect();
    arrivals.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut next_arrival = 0usize;
    let mut unfinished: Vec<FlowId> = Vec::new();
    let mut now: Time = 0.0;
    let mut step = 0usize;

    while next_arrival < arrivals.len() || !unfinished.is_empty() {
        step += 1;
        let do_arrival = next_arrival < arrivals.len()
            && (unfinished.is_empty() || rng.chance(opts.arrival_p));
        if do_arrival {
            let (t, cid) = arrivals[next_arrival];
            next_arrival += 1;
            now = now.max(t) + rng.uniform(0.0, 0.005);
            world.now = now;
            admit(&mut world, cid);
            sched.on_arrival(cid, &mut world);
            unfinished.extend(world.coflows[cid].flows.iter().copied());
        } else {
            let i = rng.below(unfinished.len());
            let fid = unfinished.swap_remove(i);
            now += rng.uniform(0.0, 0.02);
            let cid = world.flows[fid].coflow;
            let coflow_done = complete(&mut world, fid, now);
            sched.on_flow_complete(fid, &mut world);
            if coflow_done {
                sched.on_coflow_complete(cid, &mut world);
            }
        }
        if sched.tick_interval().is_some() && rng.chance(0.3) {
            sched.on_tick(&mut world);
        }
        check(sched.as_mut(), &world, kind, step);
    }
}

#[test]
fn incremental_order_equals_oracle_for_every_scheduler() {
    let opts = DriveOpts {
        ports: (4, 12),
        coflows: (2, 10),
        arrival_p: 0.4,
        aging_p: 0.33,
    };
    prop::for_all(24, |rng| {
        for &kind in SchedulerKind::all() {
            drive(kind, rng, &opts);
        }
    });
}

#[test]
fn incremental_order_equals_oracle_under_heavy_contention() {
    // One shared pair: every coflow contends on the same ports, so
    // occupancy epochs and contention terms move on almost every event.
    let opts = DriveOpts {
        ports: (2, 2),
        coflows: (2, 8),
        arrival_p: 0.5,
        aging_p: 0.0,
    };
    prop::for_all(16, |rng| {
        for &kind in &[
            SchedulerKind::Philae,
            SchedulerKind::Aalo,
            SchedulerKind::Saath,
        ] {
            drive(kind, rng, &opts);
        }
    });
}
