//! End-to-end equivalence of the incremental reallocation engine: simulated
//! CCTs must be **bit-identical** between the incremental order path
//! (`Scheduler::order_into`, the default) and the from-scratch oracle path
//! (`SimConfig::full_recompute`), across the hot-path bench scenarios.

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::sim::{SimConfig, Simulation};
use philae::trace::TraceSpec;

fn assert_bit_identical(ports: usize, coflows: usize, kind: SchedulerKind) {
    let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
    let cfg = SchedulerConfig::default();

    // The §4.3 deadline model couples *measured wall time* into tick
    // scheduling (a slow reallocation skips ticks) — by design the full
    // path is slower, so that knob must be neutralized for the two event
    // histories to be comparable at all. An effectively infinite
    // accounting δ keeps every other behavior (ordering, allocation,
    // progress, completion events) bit-for-bit deterministic.
    let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };

    let mut inc_sched = kind.build(&trace, &cfg);
    let inc = Simulation::run_with(&trace, inc_sched.as_mut(), &cfg, &base);

    let mut full_sched = kind.build(&trace, &cfg);
    let full_cfg = SimConfig { full_recompute: true, ..base };
    let full = Simulation::run_with(&trace, full_sched.as_mut(), &cfg, &full_cfg);

    assert_eq!(inc.ccts.len(), full.ccts.len());
    for (i, (a, b)) in inc.ccts.iter().zip(full.ccts.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{kind:?} {ports}p/{coflows}c: coflow {i} CCT {a} != {b}"
        );
    }
    // the whole event history must match, not just the endpoints
    assert_eq!(inc.rate_calcs, full.rate_calcs, "{kind:?}: reallocation counts diverged");
    assert_eq!(inc.rate_msgs, full.rate_msgs, "{kind:?}: rate message counts diverged");
    assert_eq!(inc.update_msgs, full.update_msgs, "{kind:?}: update counts diverged");
    assert_eq!(
        inc.makespan.to_bits(),
        full.makespan.to_bits(),
        "{kind:?}: makespan diverged"
    );
}

#[test]
fn philae_ccts_bit_identical_150_ports() {
    assert_bit_identical(150, 200, SchedulerKind::Philae);
}

#[test]
fn aalo_ccts_bit_identical_150_ports() {
    assert_bit_identical(150, 200, SchedulerKind::Aalo);
}

#[test]
fn philae_ccts_bit_identical_900_ports() {
    assert_bit_identical(900, 600, SchedulerKind::Philae);
}

#[test]
fn aalo_ccts_bit_identical_900_ports() {
    assert_bit_identical(900, 600, SchedulerKind::Aalo);
}

#[test]
fn remaining_schedulers_bit_identical_on_small_trace() {
    for &kind in &[
        SchedulerKind::Saath,
        SchedulerKind::Fifo,
        SchedulerKind::Scf,
        SchedulerKind::Sebf,
        SchedulerKind::PhilaeLcb,
    ] {
        assert_bit_identical(50, 60, kind);
    }
}
