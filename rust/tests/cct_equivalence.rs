//! End-to-end equivalence of the incremental reallocation engine: simulated
//! CCTs must be **bit-identical** between the incremental order path
//! (`Scheduler::order_into`, the default) and the from-scratch oracle path
//! (`SimConfig::full_recompute`), across the hot-path bench scenarios and
//! **all nine scheduler kinds**; between **batched admission** (the
//! default coalesced `EventBatch` delivery) and the legacy per-event
//! admission (`SimConfig::per_event_admission`); and between the
//! **multi-coordinator cluster at K=1** (`Simulation::run_cluster`) and
//! the single-coordinator path — which makes this whole suite the oracle
//! for the cluster plumbing. K ∈ {2, 4} intentionally trades schedule
//! quality for coordinator scalability and is CCT-*bounded* rather than
//! pinned.

use philae::coordinator::{DeadlineMode, SchedulerConfig, SchedulerKind};
use philae::sim::{SimConfig, SimResult, Simulation};
use philae::trace::{Trace, TraceSpec};

fn assert_bit_identical(ports: usize, coflows: usize, kind: SchedulerKind) {
    let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
    let cfg = SchedulerConfig::default();

    // The §4.3 deadline model couples *measured wall time* into tick
    // scheduling (a slow reallocation skips ticks) — by design the full
    // path is slower, so that knob must be neutralized for the two event
    // histories to be comparable at all. An effectively infinite
    // accounting δ keeps every other behavior (ordering, allocation,
    // progress, completion events) bit-for-bit deterministic.
    let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };

    let mut inc_sched = kind.build(&trace, &cfg);
    let inc = Simulation::run_with(&trace, inc_sched.as_mut(), &cfg, &base);

    let mut full_sched = kind.build(&trace, &cfg);
    let full_cfg = SimConfig { full_recompute: true, ..base };
    let full = Simulation::run_with(&trace, full_sched.as_mut(), &cfg, &full_cfg);

    assert_eq!(inc.ccts.len(), full.ccts.len());
    for (i, (a, b)) in inc.ccts.iter().zip(full.ccts.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{kind:?} {ports}p/{coflows}c: coflow {i} CCT {a} != {b}"
        );
    }
    // the whole event history must match, not just the endpoints
    assert_eq!(inc.rate_calcs, full.rate_calcs, "{kind:?}: reallocation counts diverged");
    assert_eq!(inc.rate_msgs, full.rate_msgs, "{kind:?}: rate message counts diverged");
    assert_eq!(inc.update_msgs, full.update_msgs, "{kind:?}: update counts diverged");
    assert_eq!(
        inc.makespan.to_bits(),
        full.makespan.to_bits(),
        "{kind:?}: makespan diverged"
    );
}

#[test]
fn philae_ccts_bit_identical_150_ports() {
    assert_bit_identical(150, 200, SchedulerKind::Philae);
}

#[test]
fn aalo_ccts_bit_identical_150_ports() {
    assert_bit_identical(150, 200, SchedulerKind::Aalo);
}

#[test]
fn philae_ccts_bit_identical_900_ports() {
    assert_bit_identical(900, 600, SchedulerKind::Philae);
}

#[test]
fn aalo_ccts_bit_identical_900_ports() {
    assert_bit_identical(900, 600, SchedulerKind::Aalo);
}

#[test]
fn remaining_schedulers_bit_identical_on_small_trace() {
    // philae and aalo get the dedicated large-scenario tests above; this
    // covers the other eight of the ten kinds.
    for &kind in &[
        SchedulerKind::Saath,
        SchedulerKind::Fifo,
        SchedulerKind::Scf,
        SchedulerKind::Sebf,
        SchedulerKind::PhilaeLcb,
        SchedulerKind::PhilaeEc1,
        SchedulerKind::PhilaeEcMulti,
        SchedulerKind::Dcoflow,
    ] {
        assert_bit_identical(50, 60, kind);
    }
}

/// Run one simulation under `cfg` variants for the deadline-off pin.
fn run_once(trace: &Trace, kind: SchedulerKind, cfg: &SchedulerConfig) -> SimResult {
    let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };
    let mut sched = kind.build(trace, cfg);
    Simulation::run_with(trace, sched.as_mut(), cfg, &base)
}

fn assert_same_history(a: &SimResult, b: &SimResult, what: &str) {
    assert_eq!(a.ccts.len(), b.ccts.len(), "{what}: coflow counts");
    for (i, (x, y)) in a.ccts.iter().zip(b.ccts.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: coflow {i} CCT {x} != {y}");
    }
    assert_eq!(a.rate_calcs, b.rate_calcs, "{what}: reallocation counts");
    assert_eq!(a.rate_msgs, b.rate_msgs, "{what}: rate message counts");
    assert_eq!(a.update_msgs, b.update_msgs, "{what}: update counts");
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{what}: makespan");
}

/// Deadline-off pin (the PR's "don't perturb the existing family" bar):
/// on a trace with **no deadlines**, the entire deadline plumbing —
/// `DeadlineMode::Secondary` keys included — must be invisible: every
/// scheduler's event history is bit-identical between `Ignore` and
/// `Secondary`.
#[test]
fn deadline_mode_is_identity_without_deadlines() {
    let trace = TraceSpec::fb_like(50, 60).seed(5).generate();
    for &kind in SchedulerKind::all() {
        let ignore = run_once(&trace, kind, &SchedulerConfig::default());
        let mut cfg = SchedulerConfig::default();
        cfg.deadline_mode = DeadlineMode::Secondary;
        let secondary = run_once(&trace, kind, &cfg);
        assert_same_history(&ignore, &secondary, kind.as_str());
        assert_eq!(ignore.deadline.with_deadline, 0);
        assert_eq!(ignore.deadline.met_ratio(), 1.0, "SLO-free run is vacuously met");
    }
}

/// Deadline-*presence* pin: the SLO model assigns deadlines from its own
/// RNG stream (flows/arrivals untouched), so every **deadline-blind**
/// scheduler (default `Ignore` mode) must produce a bit-identical event
/// history on the deadline-carrying twin of a trace.
#[test]
fn deadline_presence_is_invisible_to_blind_schedulers() {
    let plain = TraceSpec::fb_like(50, 60).seed(5).generate();
    let slo = TraceSpec::fb_like(50, 60)
        .seed(5)
        .with_deadline_tightness(2.0)
        .generate();
    for &kind in SchedulerKind::all() {
        if kind == SchedulerKind::Dcoflow {
            continue; // deadline-aware by design
        }
        let cfg = SchedulerConfig::default();
        let a = run_once(&plain, kind, &cfg);
        let b = run_once(&slo, kind, &cfg);
        assert_same_history(&a, &b, kind.as_str());
        // ...while the SLO accounting still sees the deadlines
        assert_eq!(b.deadline.with_deadline, slo.coflows.len());
    }
}

/// Batched admission (one coalesced `on_batch` + one allocation per
/// instant) must reproduce the per-event admission history bit for bit.
/// `jitter` > 0 additionally exercises delayed, reordered completion
/// reports — the path `queue_report`'s precomputed coflow-done flag is
/// specifically designed for.
fn assert_batched_equals_per_event(
    ports: usize,
    coflows: usize,
    kind: SchedulerKind,
    jitter: f64,
) {
    let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
    let mut cfg = SchedulerConfig::default();
    cfg.report_jitter = jitter;
    cfg.dynamics_seed = 17;
    // Neutralize the measured-wall-time deadline coupling, as above.
    let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };

    let mut batched_sched = kind.build(&trace, &cfg);
    let batched = Simulation::run_with(&trace, batched_sched.as_mut(), &cfg, &base);

    let per_event_cfg = SimConfig { per_event_admission: true, ..base };
    let mut per_event_sched = kind.build(&trace, &cfg);
    let per_event = Simulation::run_with(&trace, per_event_sched.as_mut(), &cfg, &per_event_cfg);

    assert_eq!(batched.ccts.len(), per_event.ccts.len());
    for (i, (a, b)) in batched.ccts.iter().zip(per_event.ccts.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{kind:?} {ports}p/{coflows}c: coflow {i} CCT {a} != {b} (batched vs per-event)"
        );
    }
    assert_eq!(batched.rate_calcs, per_event.rate_calcs, "{kind:?}: reallocation counts");
    assert_eq!(batched.rate_msgs, per_event.rate_msgs, "{kind:?}: rate message counts");
    assert_eq!(batched.update_msgs, per_event.update_msgs, "{kind:?}: update counts");
    assert_eq!(
        batched.makespan.to_bits(),
        per_event.makespan.to_bits(),
        "{kind:?}: makespan"
    );
}

/// The multi-coordinator cluster with K=1 is a transparent pass-through:
/// the whole event history must be bit-identical to the single path.
fn assert_cluster_k1_bit_identical(ports: usize, coflows: usize, kind: SchedulerKind) {
    let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
    let cfg = SchedulerConfig::default();
    let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };

    let mut sched = kind.build(&trace, &cfg);
    let single = Simulation::run_with(&trace, sched.as_mut(), &cfg, &base);

    let cluster_cfg = SimConfig { coordinators: 1, ..base };
    let clustered = Simulation::run_cluster(&trace, kind, &cfg, &cluster_cfg);

    assert_eq!(single.ccts.len(), clustered.ccts.len());
    for (i, (a, b)) in single.ccts.iter().zip(clustered.ccts.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{kind:?} {ports}p/{coflows}c: coflow {i} CCT {a} != {b} (single vs cluster K=1)"
        );
    }
    assert_eq!(single.rate_calcs, clustered.rate_calcs, "{kind:?}: reallocation counts");
    assert_eq!(single.rate_msgs, clustered.rate_msgs, "{kind:?}: rate message counts");
    assert_eq!(single.update_msgs, clustered.update_msgs, "{kind:?}: update counts");
    assert_eq!(
        single.makespan.to_bits(),
        clustered.makespan.to_bits(),
        "{kind:?}: makespan"
    );
}

#[test]
fn philae_cluster_k1_bit_identical_150_ports() {
    assert_cluster_k1_bit_identical(150, 200, SchedulerKind::Philae);
}

#[test]
fn aalo_cluster_k1_bit_identical_150_ports() {
    assert_cluster_k1_bit_identical(150, 200, SchedulerKind::Aalo);
}

/// K > 1 partitions coflows across shards with leased capacity — schedule
/// quality may drop (a shard only spends its lease and only orders its own
/// coflows), but every coflow must finish and the average CCT must stay
/// within a small factor of the single coordinator's.
fn assert_cluster_cct_bounded(ports: usize, coflows: usize, kind: SchedulerKind, k: usize) {
    let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
    let cfg = SchedulerConfig::default();
    let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };

    let mut sched = kind.build(&trace, &cfg);
    let single = Simulation::run_with(&trace, sched.as_mut(), &cfg, &base);

    let cluster_cfg = SimConfig { coordinators: k, ..base };
    let clustered = Simulation::run_cluster(&trace, kind, &cfg, &cluster_cfg);

    for (i, &cct) in clustered.ccts.iter().enumerate() {
        assert!(
            cct.is_finite() && cct > 0.0,
            "{kind:?} K={k}: coflow {i} never finished (cct {cct})"
        );
    }
    let ratio = clustered.avg_cct() / single.avg_cct();
    assert!(
        ratio <= 5.0,
        "{kind:?} K={k}: avg CCT blew up {ratio:.2}x over the single coordinator \
         ({:.4}s vs {:.4}s)",
        clustered.avg_cct(),
        single.avg_cct()
    );
    let makespan_ratio = clustered.makespan / single.makespan;
    assert!(
        makespan_ratio <= 5.0,
        "{kind:?} K={k}: makespan blew up {makespan_ratio:.2}x"
    );
}

#[test]
fn philae_cluster_k2_cct_bounded_150_ports() {
    assert_cluster_cct_bounded(150, 200, SchedulerKind::Philae, 2);
}

#[test]
fn philae_cluster_k4_cct_bounded_150_ports() {
    assert_cluster_cct_bounded(150, 200, SchedulerKind::Philae, 4);
}

#[test]
fn aalo_cluster_k2_cct_bounded_150_ports() {
    assert_cluster_cct_bounded(150, 200, SchedulerKind::Aalo, 2);
}

#[test]
fn aalo_cluster_k4_cct_bounded_150_ports() {
    assert_cluster_cct_bounded(150, 200, SchedulerKind::Aalo, 4);
}

#[test]
fn philae_batched_admission_cct_equivalent_150_ports() {
    assert_batched_equals_per_event(150, 200, SchedulerKind::Philae, 0.0);
}

#[test]
fn aalo_batched_admission_cct_equivalent_150_ports() {
    assert_batched_equals_per_event(150, 200, SchedulerKind::Aalo, 0.0);
}

#[test]
fn philae_batched_admission_cct_equivalent_under_report_jitter() {
    assert_batched_equals_per_event(60, 80, SchedulerKind::Philae, 0.05);
}

#[test]
fn aalo_batched_admission_cct_equivalent_under_report_jitter() {
    assert_batched_equals_per_event(60, 80, SchedulerKind::Aalo, 0.05);
}

/// Crash-failover pin (`coordinator/recovery.rs`): killing the coordinator
/// and restoring it from a freshly sealed checkpoint before every k-th
/// event delivery must reproduce the uninterrupted run bit for bit — the
/// checkpointed durable facts plus the attach rebuild carry *everything*
/// the scheduler knew, through the full production path
/// (checkpoint → seal → unseal → restore, `exact` mode).
fn assert_restore_bit_identical(trace: &Trace, kind: SchedulerKind, every: u64) {
    let cfg = SchedulerConfig::default();
    let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };

    let mut sched = kind.build(trace, &cfg);
    let plain = Simulation::run_with(trace, sched.as_mut(), &cfg, &base);

    let (restored, restores) = Simulation::run_with_restore(trace, kind, &cfg, &base, every);
    assert!(restores > 0, "{kind:?}: crash injection never fired (every={every})");
    assert_same_history(&plain, &restored, kind.as_str());
    assert_eq!(plain.deadline, restored.deadline, "{kind:?}: SLO accounting diverged");
}

#[test]
fn philae_restore_bit_identical_150_ports() {
    let trace = TraceSpec::fb_like(150, 200).seed(5).generate();
    assert_restore_bit_identical(&trace, SchedulerKind::Philae, 7);
}

#[test]
fn aalo_restore_bit_identical_150_ports() {
    let trace = TraceSpec::fb_like(150, 200).seed(5).generate();
    assert_restore_bit_identical(&trace, SchedulerKind::Aalo, 5);
}

#[test]
fn dcoflow_restore_bit_identical_with_deadlines() {
    // crash-restore across live admission verdicts and reservations
    let trace = TraceSpec::fb_like(60, 80)
        .seed(5)
        .with_deadline_tightness(2.0)
        .generate();
    assert_restore_bit_identical(&trace, SchedulerKind::Dcoflow, 3);
}

/// The deadline subsystem through the batching/cluster pipes: on a
/// deadline-carrying trace, dcoflow's batched admission must reproduce the
/// per-event history bit for bit, and the K=1 cluster must be a
/// transparent pass-through (admission counters included).
#[test]
fn dcoflow_batched_and_cluster_k1_bit_identical_with_deadlines() {
    let trace = TraceSpec::fb_like(60, 80)
        .seed(5)
        .with_deadline_tightness(2.0)
        .generate();
    let cfg = SchedulerConfig::default();
    let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };

    let mut s1 = SchedulerKind::Dcoflow.build(&trace, &cfg);
    let batched = Simulation::run_with(&trace, s1.as_mut(), &cfg, &base);

    let per_event_cfg = SimConfig { per_event_admission: true, ..base.clone() };
    let mut s2 = SchedulerKind::Dcoflow.build(&trace, &cfg);
    let per_event = Simulation::run_with(&trace, s2.as_mut(), &cfg, &per_event_cfg);
    assert_same_history(&batched, &per_event, "dcoflow batched vs per-event");
    assert_eq!(batched.deadline, per_event.deadline, "SLO accounting diverged");

    let cluster_cfg = SimConfig { coordinators: 1, ..base };
    let clustered = Simulation::run_cluster(&trace, SchedulerKind::Dcoflow, &cfg, &cluster_cfg);
    assert_same_history(&batched, &clustered, "dcoflow single vs cluster K=1");
    assert_eq!(batched.deadline, clustered.deadline, "K=1 SLO accounting diverged");
}

/// The observability plane is a pure observer: arming the flight
/// recorder + metrics registry (`SimConfig::obs_events`) must leave every
/// scheduler's event history bit-identical to the obs-off run — through
/// the single-coordinator path and the K=1 cluster frontend alike.
#[test]
fn obs_plane_is_invisible_to_scheduling() {
    let trace = TraceSpec::fb_like(50, 60).seed(5).generate();
    let cfg = SchedulerConfig::default();
    let base = SimConfig { account_delta: Some(1e18), ..SimConfig::default() };
    let obs_cfg = SimConfig { obs_events: 1 << 16, ..base.clone() };

    for &kind in SchedulerKind::all() {
        let mut off_sched = kind.build(&trace, &cfg);
        let off = Simulation::run_with(&trace, off_sched.as_mut(), &cfg, &base);
        assert!(off.obs.is_none(), "{kind:?}: obs-off run must not carry a snapshot");

        let mut on_sched = kind.build(&trace, &cfg);
        let on = Simulation::run_with(&trace, on_sched.as_mut(), &cfg, &obs_cfg);
        assert_same_history(&off, &on, &format!("{kind:?} obs off vs on"));

        let snap = on.obs.as_ref().expect("obs-on run must carry a snapshot");
        assert!(snap.recorded > 0, "{kind:?}: flight recorder saw no events");
        // every coflow completed, so every lifecycle must close
        let completes = snap
            .events
            .iter()
            .filter(|e| e.kind == philae::obs::EventKind::CoflowComplete)
            .count();
        assert_eq!(completes, trace.coflows.len(), "{kind:?}: CoflowComplete per coflow");
    }

    // same pin through the cluster frontend (K=1 is the transparent case)
    let k1_on = SimConfig { coordinators: 1, obs_events: 1 << 16, ..base.clone() };
    let k1_off = SimConfig { coordinators: 1, ..base };
    let off = Simulation::run_cluster(&trace, SchedulerKind::Philae, &cfg, &k1_off);
    let on = Simulation::run_cluster(&trace, SchedulerKind::Philae, &cfg, &k1_on);
    assert_same_history(&off, &on, "cluster K=1 obs off vs on");
    assert!(on.obs.is_some(), "cluster obs-on run must carry a snapshot");
}

/// The durable archive spool is a pure observer too: draining the rings to
/// disk on a background thread (`SimConfig::archive`) must leave every
/// scheduler's event history bit-identical to the archive-off run. This is
/// the pin that licenses arming `--archive-dir` on production-shaped runs.
#[test]
fn archive_spool_is_invisible_to_scheduling() {
    let trace = TraceSpec::fb_like(50, 60).seed(5).generate();
    let cfg = SchedulerConfig::default();
    let base = SimConfig { account_delta: Some(1e18), obs_events: 1 << 16, ..SimConfig::default() };
    let dir = std::env::temp_dir()
        .join(format!("philae_cct_arc_{}", std::process::id()));

    for &kind in SchedulerKind::all() {
        let _ = std::fs::remove_dir_all(&dir);
        let mut off_sched = kind.build(&trace, &cfg);
        let off = Simulation::run_with(&trace, off_sched.as_mut(), &cfg, &base);

        let armed_cfg = SimConfig {
            archive: Some(philae::obs::ArchiveConfig::new(&dir)),
            ..base.clone()
        };
        let mut on_sched = kind.build(&trace, &cfg);
        let on = Simulation::run_with(&trace, on_sched.as_mut(), &cfg, &armed_cfg);
        assert_same_history(&off, &on, &format!("{kind:?} archive off vs on"));

        let stats = on
            .obs
            .as_ref()
            .and_then(|s| s.archive)
            .expect("archive-armed run must carry spool stats");
        assert_eq!(
            stats.spooled,
            stats.kept + stats.dropped_ring + stats.dropped_spool,
            "{kind:?}: archive accounting identity broken"
        );
        assert_eq!(stats.io_errors, 0, "{kind:?}: archive spool hit io errors");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
