//! Integration pins for the observability plane (flight recorder +
//! metrics registry) riding the simulation engine:
//!
//! - the bounded per-shard ring really is bounded — a tiny
//!   `obs_events` cap drops the oldest events and says so;
//! - the streamed engine records the same lifecycle story as the
//!   materialized engine (modulo `Retire`, which only the streaming
//!   path's slot recycling emits);
//! - the exported snapshot round-trips through the crate's own JSON
//!   parser under the pinned `philae.obs.v1` schema, and the CSV /
//!   Chrome-trace exports are well-formed;
//! - `explain` decomposes a completed coflow's lifetime into
//!   contiguous segments that cover arrival → completion.

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::obs::{EventKind, SegmentKind};
use philae::sim::{SimConfig, SimResult, Simulation};
use philae::trace::TraceSpec;
use philae::util::JsonValue;

fn run_obs(ports: usize, coflows: usize, kind: SchedulerKind, ring: usize) -> SimResult {
    let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
    let cfg = SchedulerConfig::default();
    let sim_cfg = SimConfig {
        account_delta: Some(1e18),
        obs_events: ring,
        ..SimConfig::default()
    };
    let mut sched = kind.build(&trace, &cfg);
    Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg)
}

#[test]
fn tiny_ring_wraps_and_reports_drops() {
    let res = run_obs(50, 60, SchedulerKind::Philae, 64);
    let snap = res.obs.as_ref().expect("obs snapshot");
    assert!(snap.recorded > 64, "run too small to exercise wraparound");
    assert_eq!(snap.events.len(), 64, "kept events must equal the ring capacity");
    assert_eq!(
        snap.dropped,
        snap.recorded - 64,
        "drop accounting must balance: recorded = kept + dropped"
    );
    // the ring keeps the *newest* events: the tail of the run survives
    assert!(
        snap.events.iter().any(|e| e.kind == EventKind::CoflowComplete),
        "newest-event retention must keep the final completions"
    );
}

#[test]
fn streamed_engine_records_same_lifecycle_as_materialized() {
    let spec = TraceSpec::tiny(10, 30).seed(7);
    let trace = spec.generate();
    let cfg = SchedulerConfig::default();
    let sim_cfg = SimConfig {
        account_delta: Some(1e18),
        obs_events: 1 << 16,
        ..SimConfig::default()
    };

    let kind = SchedulerKind::Philae;
    let mut sched = kind.build(&trace, &cfg);
    let mat = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg);
    let mut stream = spec.stream();
    let str_res = Simulation::run_stream(&mut stream, kind, &cfg, &sim_cfg);

    // Retire is streaming-only (slot recycling); everything else —
    // including FlowComplete, which carries the admission-stable flow
    // *sequence* precisely so the two modes can agree — must match.
    let key = |r: &SimResult| -> Vec<(u64, &'static str, u64, u64, u64)> {
        r.obs
            .as_ref()
            .expect("obs snapshot")
            .events
            .iter()
            .filter(|e| e.kind != EventKind::Retire)
            .map(|e| (e.t.to_bits(), e.kind.as_str(), e.coflow, e.a, e.b))
            .collect()
    };
    assert_eq!(key(&mat), key(&str_res), "streamed vs materialized event logs diverged");
}

#[test]
fn snapshot_exports_are_well_formed() {
    let res = run_obs(50, 60, SchedulerKind::Philae, 1 << 16);
    let snap = res.obs.as_ref().expect("obs snapshot");
    assert_eq!(snap.dropped, 0, "ring sized for the whole run");

    // JSON snapshot: pinned schema, registry + event log present
    let json = JsonValue::parse(&snap.to_json().to_string()).expect("snapshot JSON parses");
    assert_eq!(
        json.get("schema").and_then(|v| v.as_str()),
        Some("philae.obs.v1"),
        "schema tag"
    );
    assert!(json.get("registry").is_some(), "registry section");
    let kept = json
        .get("events")
        .and_then(|e| e.get("kept"))
        .and_then(|v| v.as_f64())
        .expect("events.kept");
    assert_eq!(kept as usize, snap.events.len());
    let log = json
        .get("event_log")
        .and_then(|v| v.as_array())
        .expect("event_log array");
    assert_eq!(log.len(), snap.events.len());

    // CSV: header plus one row per kept event
    let csv = snap.to_csv();
    let mut lines = csv.lines();
    assert_eq!(lines.next(), Some("seq,t,wall_ns,shard,kind,coflow,a,b"));
    assert_eq!(lines.count(), snap.events.len());

    // Chrome trace: an object carrying a traceEvents array with at
    // least one complete ("X") span
    let trace_json = JsonValue::parse(&snap.chrome_trace_json()).expect("chrome trace parses");
    let arr = trace_json
        .get("traceEvents")
        .and_then(|v| v.as_array())
        .expect("traceEvents array");
    assert!(!arr.is_empty(), "chrome trace must carry spans");
    assert!(
        arr.iter()
            .any(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X")),
        "at least one complete span"
    );
}

#[test]
fn explain_covers_arrival_to_completion() {
    let res = run_obs(50, 60, SchedulerKind::Philae, 1 << 16);
    let snap = res.obs.as_ref().expect("obs snapshot");
    let timelines = snap.timelines();
    assert_eq!(timelines.len(), 60, "one timeline per coflow");

    let tl = snap.explain(0).expect("coflow 0 timeline");
    let finished = tl.finished.expect("coflow 0 completed");
    assert!(finished > tl.arrival, "completion after arrival");
    assert!(!tl.segments.is_empty(), "timeline has segments");
    // segments are contiguous and cover the whole lifetime
    let mut cursor = tl.arrival;
    for seg in &tl.segments {
        assert_eq!(seg.start.to_bits(), cursor.to_bits(), "segments must be contiguous");
        assert!(seg.end >= seg.start);
        cursor = seg.end;
    }
    assert_eq!(cursor.to_bits(), finished.to_bits(), "segments must end at completion");
    // decomposition adds back up to the CCT
    let total: f64 = [
        SegmentKind::Waiting,
        SegmentKind::Sampling,
        SegmentKind::Scheduled,
        SegmentKind::Starved,
    ]
    .iter()
    .map(|&k| tl.total(k))
    .sum();
    let cct = finished - tl.arrival;
    assert!(
        (total - cct).abs() <= 1e-9 * cct.max(1.0),
        "segment totals {total} must recompose the CCT {cct}"
    );
    // the human rendering mentions the coflow and every segment class total
    let report = tl.render();
    assert!(report.contains("coflow 0"), "render names the coflow: {report}");
    assert!(report.contains("scheduled"), "render lists segment classes: {report}");

    // (not NO_COFLOW — that sentinel tags plane-wide events, not a coflow)
    assert!(snap.explain(1 << 60).is_none(), "unknown coflow yields no timeline");
}
