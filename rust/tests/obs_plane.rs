//! Integration pins for the observability plane (flight recorder +
//! metrics registry) riding the simulation engine:
//!
//! - the bounded per-shard ring really is bounded — a tiny
//!   `obs_events` cap drops the oldest events and says so;
//! - the streamed engine records the same lifecycle story as the
//!   materialized engine (modulo `Retire`, which only the streaming
//!   path's slot recycling emits);
//! - the exported snapshot round-trips through the crate's own JSON
//!   parser under the pinned `philae.obs.v1` schema, and the CSV /
//!   Chrome-trace exports are well-formed;
//! - `explain` decomposes a completed coflow's lifetime into
//!   contiguous segments that cover arrival → completion;
//! - the durable archive spool keeps a byte-exact copy of a drop-free
//!   run's ring log, replayable (and `explain --all`-queryable) from
//!   disk alone;
//! - the per-port heatmap rides the engine and conserves bytes.

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::obs::{ArchiveConfig, ArchiveReader, Event, EventKind, SegmentKind};
use philae::sim::{SimConfig, SimResult, Simulation};
use philae::trace::TraceSpec;
use philae::util::JsonValue;
use std::path::PathBuf;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("philae_obsit_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn run_obs(ports: usize, coflows: usize, kind: SchedulerKind, ring: usize) -> SimResult {
    let trace = TraceSpec::fb_like(ports, coflows).seed(5).generate();
    let cfg = SchedulerConfig::default();
    let sim_cfg = SimConfig {
        account_delta: Some(1e18),
        obs_events: ring,
        ..SimConfig::default()
    };
    let mut sched = kind.build(&trace, &cfg);
    Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg)
}

#[test]
fn tiny_ring_wraps_and_reports_drops() {
    let res = run_obs(50, 60, SchedulerKind::Philae, 64);
    let snap = res.obs.as_ref().expect("obs snapshot");
    assert!(snap.recorded > 64, "run too small to exercise wraparound");
    assert_eq!(snap.events.len(), 64, "kept events must equal the ring capacity");
    assert_eq!(
        snap.dropped,
        snap.recorded - 64,
        "drop accounting must balance: recorded = kept + dropped"
    );
    // the ring keeps the *newest* events: the tail of the run survives
    assert!(
        snap.events.iter().any(|e| e.kind == EventKind::CoflowComplete),
        "newest-event retention must keep the final completions"
    );
}

#[test]
fn streamed_engine_records_same_lifecycle_as_materialized() {
    let spec = TraceSpec::tiny(10, 30).seed(7);
    let trace = spec.generate();
    let cfg = SchedulerConfig::default();
    let sim_cfg = SimConfig {
        account_delta: Some(1e18),
        obs_events: 1 << 16,
        ..SimConfig::default()
    };

    let kind = SchedulerKind::Philae;
    let mut sched = kind.build(&trace, &cfg);
    let mat = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg);
    let mut stream = spec.stream();
    let str_res = Simulation::run_stream(&mut stream, kind, &cfg, &sim_cfg);

    // Retire is streaming-only (slot recycling); everything else —
    // including FlowComplete, which carries the admission-stable flow
    // *sequence* precisely so the two modes can agree — must match.
    let key = |r: &SimResult| -> Vec<(u64, &'static str, u64, u64, u64)> {
        r.obs
            .as_ref()
            .expect("obs snapshot")
            .events
            .iter()
            .filter(|e| e.kind != EventKind::Retire)
            .map(|e| (e.t.to_bits(), e.kind.as_str(), e.coflow, e.a, e.b))
            .collect()
    };
    assert_eq!(key(&mat), key(&str_res), "streamed vs materialized event logs diverged");
}

#[test]
fn snapshot_exports_are_well_formed() {
    let res = run_obs(50, 60, SchedulerKind::Philae, 1 << 16);
    let snap = res.obs.as_ref().expect("obs snapshot");
    assert_eq!(snap.dropped, 0, "ring sized for the whole run");

    // JSON snapshot: pinned schema, registry + event log present
    let json = JsonValue::parse(&snap.to_json().to_string()).expect("snapshot JSON parses");
    assert_eq!(
        json.get("schema").and_then(|v| v.as_str()),
        Some("philae.obs.v1"),
        "schema tag"
    );
    assert!(json.get("registry").is_some(), "registry section");
    let kept = json
        .get("events")
        .and_then(|e| e.get("kept"))
        .and_then(|v| v.as_f64())
        .expect("events.kept");
    assert_eq!(kept as usize, snap.events.len());
    let log = json
        .get("event_log")
        .and_then(|v| v.as_array())
        .expect("event_log array");
    assert_eq!(log.len(), snap.events.len());

    // CSV: header plus one row per kept event
    let csv = snap.to_csv();
    let mut lines = csv.lines();
    assert_eq!(lines.next(), Some("seq,t,wall_ns,shard,kind,coflow,a,b"));
    assert_eq!(lines.count(), snap.events.len());

    // Chrome trace: an object carrying a traceEvents array with at
    // least one complete ("X") span
    let trace_json = JsonValue::parse(&snap.chrome_trace_json()).expect("chrome trace parses");
    let arr = trace_json
        .get("traceEvents")
        .and_then(|v| v.as_array())
        .expect("traceEvents array");
    assert!(!arr.is_empty(), "chrome trace must carry spans");
    assert!(
        arr.iter()
            .any(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X")),
        "at least one complete span"
    );
}

#[test]
fn explain_covers_arrival_to_completion() {
    let res = run_obs(50, 60, SchedulerKind::Philae, 1 << 16);
    let snap = res.obs.as_ref().expect("obs snapshot");
    let timelines = snap.timelines();
    assert_eq!(timelines.len(), 60, "one timeline per coflow");

    let tl = snap.explain(0).expect("coflow 0 timeline");
    let finished = tl.finished.expect("coflow 0 completed");
    assert!(finished > tl.arrival, "completion after arrival");
    assert!(!tl.segments.is_empty(), "timeline has segments");
    // segments are contiguous and cover the whole lifetime
    let mut cursor = tl.arrival;
    for seg in &tl.segments {
        assert_eq!(seg.start.to_bits(), cursor.to_bits(), "segments must be contiguous");
        assert!(seg.end >= seg.start);
        cursor = seg.end;
    }
    assert_eq!(cursor.to_bits(), finished.to_bits(), "segments must end at completion");
    // decomposition adds back up to the CCT
    let total: f64 = [
        SegmentKind::Waiting,
        SegmentKind::Sampling,
        SegmentKind::Scheduled,
        SegmentKind::Starved,
    ]
    .iter()
    .map(|&k| tl.total(k))
    .sum();
    let cct = finished - tl.arrival;
    assert!(
        (total - cct).abs() <= 1e-9 * cct.max(1.0),
        "segment totals {total} must recompose the CCT {cct}"
    );
    // the human rendering mentions the coflow and every segment class total
    let report = tl.render();
    assert!(report.contains("coflow 0"), "render names the coflow: {report}");
    assert!(report.contains("scheduled"), "render lists segment classes: {report}");

    // (not NO_COFLOW — that sentinel tags plane-wide events, not a coflow)
    assert!(snap.explain(1 << 60).is_none(), "unknown coflow yields no timeline");
}

#[test]
fn archived_run_replays_bit_identically_to_the_ring() {
    let dir = tmp_dir("parity");
    let trace = TraceSpec::fb_like(50, 60).seed(5).generate();
    let cfg = SchedulerConfig::default();
    let sim_cfg = SimConfig {
        account_delta: Some(1e18),
        obs_events: 1 << 16,
        archive: Some(ArchiveConfig::new(&dir)),
        ..SimConfig::default()
    };
    let mut sched = SchedulerKind::Philae.build(&trace, &cfg);
    let res = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg);
    let snap = res.obs.as_ref().expect("obs snapshot");
    assert_eq!(snap.dropped, 0, "ring sized for the whole run");

    // backpressure accounting: spooled = kept + dropped_ring + dropped_spool,
    // and a drop-free run keeps everything the plane recorded
    let stats = snap.archive.expect("archive stats ride the snapshot");
    assert_eq!(stats.spooled, stats.kept + stats.dropped_ring + stats.dropped_spool);
    assert_eq!(stats.dropped_ring + stats.dropped_spool, 0, "drop-free run");
    assert_eq!(stats.kept, snap.recorded, "spool kept every recorded event");
    assert_eq!(stats.io_errors, 0);

    // the on-disk segments replay to the exact ring log
    let replay = ArchiveReader::read_dir(&dir).expect("replay archive");
    let key = |events: &[Event]| -> Vec<(u64, u64, u32, &'static str, u64, u64, u64)> {
        events
            .iter()
            .map(|e| (e.t.to_bits(), e.seq, e.shard, e.kind.as_str(), e.coflow, e.a, e.b))
            .collect()
    };
    assert_eq!(key(&replay.events), key(&snap.events), "archive replay diverged from the ring");
    assert_eq!(replay.truncated, 0, "clean shutdown leaves no torn tail");
    assert_eq!(replay.stats.map(|s| s.kept), Some(stats.kept), "archive.json stats round-trip");

    // the fleet-wide CCT decomposition works from disk alone
    let offline = ArchiveReader::snapshot(&dir).expect("offline snapshot");
    assert_eq!(
        offline.explain_all_csv(),
        snap.explain_all_csv(),
        "explain --all from the archive must match the live snapshot"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn heatmap_rides_the_engine_and_conserves_bytes() {
    let trace = TraceSpec::fb_like(50, 60).seed(5).generate();
    let cfg = SchedulerConfig::default();
    let sim_cfg = SimConfig { obs_events: 1 << 16, heatmap_bins: 16, ..SimConfig::default() };
    let mut sched = SchedulerKind::Philae.build(&trace, &cfg);
    let res = Simulation::run_with(&trace, sched.as_mut(), &cfg, &sim_cfg);
    let snap = res.obs.as_ref().expect("obs snapshot");
    let hm = snap.heatmap.as_ref().expect("heatmap armed via heatmap_bins");
    assert_eq!(hm.bins(), 16);
    assert_eq!(hm.ports(), 50);

    let csv = hm.to_csv();
    assert!(csv.starts_with("port,dir,bin,t_start,t_end,bytes,utilization\n"));
    assert!(csv.lines().count() > 1, "a real run moves bytes into some bin");

    let json = JsonValue::parse(&hm.to_json().to_string()).expect("heatmap JSON parses");
    assert_eq!(
        json.get("schema").and_then(|v| v.as_str()),
        Some("philae.obs.heatmap.v1"),
        "schema tag"
    );
    let sum = |key: &str| -> f64 {
        json.get(key)
            .and_then(|v| v.as_array())
            .expect("byte matrix")
            .iter()
            .flat_map(|row| row.as_array().expect("matrix row").iter())
            .map(|v| v.as_f64().expect("matrix cell"))
            .sum()
    };
    let (up, down) = (sum("up_bytes"), sum("down_bytes"));
    assert!(up > 0.0, "the run moved bytes");
    assert!(
        (up - down).abs() <= 1e-6 * up,
        "every byte leaves a sender and enters a receiver: up {up} vs down {down}"
    );
}
