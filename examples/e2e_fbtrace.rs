//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! Replays an FB-like trace through the **live coordinator service** — one
//! OS thread per local agent, the coordinator scoring coflows through the
//! **AOT-compiled JAX/Pallas artifacts via PJRT** (when `artifacts/` exists;
//! build with `make artifacts`) — and reports the paper's headline metric
//! (avg/P50/P90 CCT speedup over Aalo) plus the measured coordinator
//! per-interval phase times of Tables 3/4.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_fbtrace
//! ```

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::metrics::SpeedupRow;
use philae::service::{run_service, ServiceConfig, ServiceReport};
use philae::trace::TraceSpec;
use std::time::Duration;

fn report(name: &str, r: &ServiceReport) {
    println!(
        "{name} (engine={}): avg CCT {:.3}s | rate msgs {} | updates {} | wall {:.1}s",
        r.used_engine,
        r.avg_cct(),
        r.rate_msgs,
        r.update_msgs,
        r.wall_seconds
    );
    println!(
        "  per-interval ms: calc {:.3} ({:.3}) | send {:.3} ({:.3}) | recv {:.3} ({:.3})",
        r.rate_calc.mean() * 1e3,
        r.rate_calc.stddev() * 1e3,
        r.rate_send.mean() * 1e3,
        r.rate_send.stddev() * 1e3,
        r.update_recv.mean() * 1e3,
        r.update_recv.stddev() * 1e3,
    );
    println!(
        "  intervals > δ: {:.1}% | intervals with no rate flush: {:.1}%",
        100.0 * r.missed_fraction,
        100.0 * r.idle_rate_fraction
    );
}

fn main() -> anyhow::Result<()> {
    // A 45-coflow, 40-port slice of the FB-like workload, replayed 60×
    // faster than real time so the run takes ~20 s of wall clock.
    let trace = TraceSpec::fb_like(40, 45)
        .with_load_factor(4.0)
        .seed(9)
        .generate();
    println!(
        "workload: {} coflows / {} flows / {:.2} GB on {} ports\n",
        trace.coflows.len(),
        trace.flows.len(),
        trace.total_bytes() / 1e9,
        trace.num_ports
    );

    let artifacts = std::path::Path::new("artifacts/manifest.json")
        .exists()
        .then(|| std::path::PathBuf::from("artifacts"));
    if artifacts.is_none() {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts` to exercise the PJRT path;");
        eprintln!("      falling back to the native scorer.\n");
    }

    let base = ServiceConfig {
        kind: SchedulerKind::Philae,
        sched: SchedulerConfig::default(),
        time_scale: 60.0,
        delta_wall: Duration::from_millis(8),
        engine_dir: artifacts,
        port_rate: philae::GBPS,
        alloc_shards: 1,
        coordinators: 1,
        // resilience + observability knobs stay at their defaults (off)
        ..ServiceConfig::default()
    };

    let philae_run = run_service(&trace, &base)?;
    report("philae", &philae_run);
    println!();

    let aalo_cfg = ServiceConfig {
        kind: SchedulerKind::Aalo,
        engine_dir: None,
        ..base.clone()
    };
    let aalo_run = run_service(&trace, &aalo_cfg)?;
    report("aalo", &aalo_run);

    let row = SpeedupRow::from_ccts(&aalo_run.ccts, &philae_run.ccts);
    println!("\n== headline (live service, measured) ==");
    println!("philae vs aalo: {row}");
    println!(
        "coordinator work: philae {:.1} ms/interval vs aalo {:.1} ms/interval",
        philae_run.intervals.total_ms_mean(),
        aalo_run.intervals.total_ms_mean()
    );
    Ok(())
}
