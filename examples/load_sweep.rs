//! Load-sweep ablation: how the Philae-vs-Aalo gap depends on offered
//! load (the paper's “coflow scheduling is of high relevance in a busy
//! cluster” claim, §2.1). Also an ablation for DESIGN.md §5's calibration
//! of the FB-like operating point.
//!
//! ```bash
//! cargo run --release --example load_sweep
//! ```
use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::metrics::SpeedupRow;
use philae::sim::Simulation;
use philae::trace::TraceSpec;

fn main() {
    for load in [1.0, 2.0, 4.0, 8.0] {
        let trace = TraceSpec::fb_like(150, 526).with_load_factor(load).seed(42).generate();
        let cfg = SchedulerConfig::default();
        let aalo = Simulation::run(&trace, SchedulerKind::Aalo, &cfg);
        let ph = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
        let scf = Simulation::run(&trace, SchedulerKind::Sebf, &cfg);
        let fifo = Simulation::run(&trace, SchedulerKind::Fifo, &cfg);
        let row = SpeedupRow::from_ccts(&aalo.ccts, &ph.ccts);
        println!(
            "load {load}: philae/aalo {row} | avg: sebf {:.1} philae {:.1} aalo {:.1} fifo {:.1}",
            scf.avg_cct(), ph.avg_cct(), aalo.avg_cct(), fifo.avg_cct()
        );
    }
}
