//! Regenerate every table and figure of the paper's evaluation (§4) on the
//! FB-like synthetic trace. See DESIGN.md §4 for the experiment index and
//! EXPERIMENTS.md for recorded paper-vs-measured results.
//!
//! ```bash
//! cargo run --release --example paper_tables            # full set
//! cargo run --release --example paper_tables -- --quick # smaller trace
//! ```

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::metrics::{
    cdf, jct_speedups, mean, mean_normalized_stddev, percentile, MessageCostModel,
    ShuffleFractionModel, SpeedupRow,
};
use philae::sim::{SimConfig, Simulation, SimResult};
use philae::trace::{Trace, TraceSpec};

/// The calibrated FB-like operating point (DESIGN.md §3): the published
/// trace is far burstier/denser than a Poisson process, so the generator is
/// run at 4× load compression to land in the paper's contention regime.
fn fb_trace(ports: usize, coflows: usize, seed: u64) -> Trace {
    TraceSpec::fb_like(ports, coflows)
        .with_load_factor(4.0)
        .seed(seed)
        .generate()
}

fn run(trace: &Trace, kind: SchedulerKind, cfg: &SchedulerConfig) -> SimResult {
    Simulation::run(trace, kind, cfg)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (ports, coflows) = if quick { (50, 150) } else { (150, 526) };
    let cfg = SchedulerConfig::default();
    let trace = fb_trace(ports, coflows, 42);
    println!(
        "workload: {} coflows / {} flows / {:.1} GB on {} ports\n",
        trace.coflows.len(),
        trace.flows.len(),
        trace.total_bytes() / 1e9,
        trace.num_ports
    );

    let aalo = run(&trace, SchedulerKind::Aalo, &cfg);
    let philae = run(&trace, SchedulerKind::Philae, &cfg);

    // ---------------- Table 2: CCT improvement ----------------
    println!("== Table 2: CCT improvement, Philae vs Aalo ==");
    println!("paper:    FB trace  P50 1.63x  P90 8.00x  avg-CCT 1.50x");
    let row = SpeedupRow::from_ccts(&aalo.ccts, &philae.ccts);
    println!("measured: FB-like   {row}");
    let wide = trace.wide_only();
    let aalo_w = run(&wide, SchedulerKind::Aalo, &cfg);
    let philae_w = run(&wide, SchedulerKind::Philae, &cfg);
    let row_w = SpeedupRow::from_ccts(&aalo_w.ccts, &philae_w.ccts);
    println!("paper:    Wide-only P50 1.05x  P90 2.14x  avg-CCT 1.49x");
    println!("measured: Wide-only {row_w}\n");

    // ---------------- Figure: CDF of CCT speedups ----------------
    println!("== Figure: CDF of per-coflow CCT speedup (Aalo/Philae) ==");
    let speedups = philae::metrics::speedups(&aalo.ccts, &philae.ccts);
    for (v, q) in cdf(&speedups, 10) {
        println!("  q={q:.2}  speedup={v:.2}x");
    }
    println!();

    // ---------------- Figure + §4.2: JCT ----------------
    println!("== §4.2: Job completion time (shuffle-fraction model) ==");
    println!("paper:    P50 1.16x  P90 7.87x");
    let jct = jct_speedups(&aalo.ccts, &philae.ccts, &ShuffleFractionModel::default());
    println!(
        "measured: P50 {:.2}x  P90 {:.2}x  mean {:.2}x\n",
        percentile(&jct, 50.0),
        percentile(&jct, 90.0),
        mean(&jct)
    );

    // ---------------- Table 1: interaction economics ----------------
    println!("== Table 1: coordinator↔agent interaction counts ==");
    println!(
        "  updates received:  philae {:>10}   aalo {:>10}  ({:.0}x more)",
        philae.update_msgs,
        aalo.update_msgs,
        aalo.update_msgs as f64 / philae.update_msgs.max(1) as f64
    );
    println!(
        "  rate calculations: philae {:>10}   aalo {:>10}",
        philae.rate_calcs, aalo.rate_calcs
    );
    println!(
        "  idle-rate intervals: philae {:.0}%  aalo {:.0}%  (paper: philae skipped 66%)\n",
        100.0 * philae.intervals.idle_rate_fraction(),
        100.0 * aalo.intervals.idle_rate_fraction()
    );

    // ---------------- Table 3: coordinator time per interval ----------------
    println!("== Table 3: coordinator ms per scheduling interval (900 ports) ==");
    println!("paper:  philae total 14.80 (28.84) | aalo total 32.90 (34.09)");
    let k = if quick { 2 } else { 6 };
    let trace9 = trace.replicate(k);
    let mut cfg9 = cfg.clone();
    cfg9.delta *= k as f64; // δ' = kδ, as §4.3
    let philae9 = run(&trace9, SchedulerKind::Philae, &cfg9);
    let aalo9 = run(&trace9, SchedulerKind::Aalo, &cfg9);
    for (name, r) in [("philae", &philae9), ("aalo", &aalo9)] {
        println!(
            "  {name:>6}: calc {:.2} ({:.2})  send {:.2} ({:.2})  recv {:.2} ({:.2})  total {:.2} ms",
            r.intervals.rate_calc.mean() * 1e3,
            r.intervals.rate_calc.stddev() * 1e3,
            r.intervals.rate_send.mean() * 1e3,
            r.intervals.rate_send.stddev() * 1e3,
            r.intervals.update_recv.mean() * 1e3,
            r.intervals.update_recv.stddev() * 1e3,
            r.intervals.total_ms_mean(),
        );
    }
    println!(
        "  agents reporting/interval: philae {:.0} vs aalo {:.0} (paper: 49 vs 429)\n",
        philae9.intervals.updates_per_interval.mean(),
        aalo9.intervals.updates_per_interval.mean()
    );

    // ---------------- Table 4 + §4.3: missed deadlines & 900-port CCT ----------------
    println!("== Table 4: % intervals exceeding δ ==");
    println!("paper:  150 ports: philae 1%  aalo 16% | 900 ports: philae 10%  aalo 37%");
    println!(
        "measured {} ports: philae {:.0}%  aalo {:.0}% | {} ports: philae {:.0}%  aalo {:.0}%",
        trace.num_ports,
        100.0 * philae.intervals.missed_fraction(),
        100.0 * aalo.intervals.missed_fraction(),
        trace9.num_ports,
        100.0 * philae9.intervals.missed_fraction(),
        100.0 * aalo9.intervals.missed_fraction(),
    );
    let row9 = SpeedupRow::from_ccts(&aalo9.ccts, &philae9.ccts);
    println!("paper:    900-port CCT avg 2.72x (P90 9.78x)");
    println!("measured: {}-port CCT {row9}\n", trace9.num_ports);

    // ---------------- §2.2: error-correction variants ----------------
    println!("== §2.2: error-correction variants (avg-CCT speedup vs Aalo) ==");
    println!("paper:  default 1.51x | LCB 1.33x | 1-round 1.27x | multi-round 0.95x");
    print!("measured:");
    for (label, kind) in [
        ("default", SchedulerKind::Philae),
        ("LCB", SchedulerKind::PhilaeLcb),
        ("1-round", SchedulerKind::PhilaeEc1),
        ("multi-round", SchedulerKind::PhilaeEcMulti),
    ] {
        let r = run(&trace, kind, &cfg);
        print!(" {label} {:.2}x |", aalo.avg_cct() / r.avg_cct());
    }
    println!("\n");

    // ---------------- Table 5: run-to-run robustness ----------------
    println!("== Table 5: mean-normalized stddev of CCT across 5 noisy runs ==");
    println!("paper:  avg-CCT — philae 0.1%  aalo 1.6% ; P50 — 2.3% vs 4.4%");
    let mut stats: Vec<(&str, Vec<f64>, Vec<f64>)> = Vec::new();
    for kind in [SchedulerKind::Philae, SchedulerKind::Aalo] {
        let mut avgs = Vec::new();
        let mut p50s = Vec::new();
        for seed in 0..5u64 {
            let mut c = cfg.clone();
            c.dynamics_seed = seed;
            c.report_jitter = 0.02;
            c.update_loss_prob = 0.05;
            let r = run(&trace, kind, &c);
            avgs.push(r.avg_cct());
            p50s.push(percentile(&r.ccts, 50.0));
        }
        stats.push((
            if kind == SchedulerKind::Philae { "philae" } else { "aalo" },
            avgs,
            p50s,
        ));
    }
    for (name, avgs, p50s) in &stats {
        println!(
            "  {name:>6}: avg-CCT {:.2}%  P50 {:.2}%",
            100.0 * mean_normalized_stddev(avgs),
            100.0 * mean_normalized_stddev(p50s)
        );
    }
    println!();

    // ---------------- Table 6: resource usage ----------------
    println!("== Table 6: coordinator resource-usage proxies ==");
    println!("paper:  coordinator CPU 3.4x lower (overall), 2.6x (busy); memory 318→212 MB");
    let costs = MessageCostModel::default();
    let bp = philae.coordinator_busy_s(&costs);
    let ba = aalo.coordinator_busy_s(&costs);
    println!(
        "  busy seconds: philae {bp:.1}s vs aalo {ba:.1}s  ({:.1}x lower)",
        ba / bp
    );
    println!(
        "  peak working set: philae {} coflows / {} flows",
        philae.peak_active_coflows, philae.peak_active_flows
    );
    println!(
        "  baselines (avg CCT): sebf {:.1}s  scf {:.1}s  saath {:.1}s  fifo {:.1}s vs philae {:.1}s",
        run(&trace, SchedulerKind::Sebf, &cfg).avg_cct(),
        run(&trace, SchedulerKind::Scf, &cfg).avg_cct(),
        run(&trace, SchedulerKind::Saath, &cfg).avg_cct(),
        run(&trace, SchedulerKind::Fifo, &cfg).avg_cct(),
        philae.avg_cct(),
    );
    let _ = SimConfig::default();
}
