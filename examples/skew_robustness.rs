//! §2.2 robustness study: is sampling-based learning robust to intra-coflow
//! flow-size skew? Sweeps the generator's lognormal σ (skew = max/min flow
//! length grows with σ) and the pilot count, and checks the measured CCT
//! gap against the Hoeffding bound of Eq. (1).
//!
//! ```bash
//! cargo run --release --example skew_robustness
//! ```

use philae::analysis::{skew_distribution, TwoCoflowSetting};
use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::metrics::percentile;
use philae::sim::Simulation;
use philae::trace::TraceSpec;

fn main() {
    println!("== Eq. (1): analytic Hoeffding bound on the sampling CCT gap ==");
    println!("{:>8} {:>8} {:>12}", "skew h", "pilots", "bound");
    for h in [0.1, 0.5, 0.9] {
        for m in [1.0, 4.0, 10.0] {
            let s = TwoCoflowSetting::symmetric(200.0, 10.0, h, 1.2, m);
            println!("{h:>8.1} {m:>8.0} {:>12.4}", s.hoeffding_bound());
        }
    }

    println!("\n== Simulated: CCT vs clairvoyant SCF across skew ==");
    println!(
        "{:>6} {:>12} {:>14} {:>14} {:>12}",
        "σ", "median skew", "philae/sebf", "aalo/sebf", "phi vs aalo"
    );
    let cfg = SchedulerConfig::default();
    for sigma in [0.2, 0.8, 1.2, 2.0] {
        let trace = TraceSpec::fb_like(100, 300)
            .with_skew_sigma(sigma)
            .with_load_factor(4.0)
            .seed(11)
            .generate();
        let sk = skew_distribution(&trace);
        let philae = Simulation::run(&trace, SchedulerKind::Philae, &cfg);
        let aalo = Simulation::run(&trace, SchedulerKind::Aalo, &cfg);
        let sebf = Simulation::run(&trace, SchedulerKind::Sebf, &cfg);
        println!(
            "{sigma:>6.1} {:>12.1} {:>14.3} {:>14.3} {:>12.2}x",
            percentile(&sk, 50.0),
            philae.avg_cct() / sebf.avg_cct(),
            aalo.avg_cct() / sebf.avg_cct(),
            aalo.avg_cct() / philae.avg_cct(),
        );
    }
    println!("\n(sampling stays within a bounded factor of the oracle even as");
    println!(" skew grows — the paper's robustness claim; see EXPERIMENTS.md)");

    println!("\n== Pilot-count ablation (σ=1.2, load 4x) ==");
    let trace = TraceSpec::fb_like(100, 300).with_load_factor(4.0).seed(11).generate();
    let sebf = Simulation::run(&trace, SchedulerKind::Sebf, &cfg);
    for pilots in [1usize, 2, 5, 10, 16] {
        let mut c = cfg.clone();
        c.pilot_min = 1;
        c.pilot_max = pilots;
        c.pilot_frac = pilots as f64 / 100.0;
        let r = Simulation::run(&trace, SchedulerKind::Philae, &c);
        println!(
            "  pilot_max {pilots:>3}: philae/sebf {:.3}  (avg CCT {:.2}s)",
            r.avg_cct() / sebf.avg_cct(),
            r.avg_cct()
        );
    }
}
