//! Quickstart: generate a small FB-like workload, run Philae and Aalo
//! through the discrete-event simulator, and print the headline comparison.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use philae::coordinator::{SchedulerConfig, SchedulerKind};
use philae::metrics::SpeedupRow;
use philae::sim::Simulation;
use philae::trace::TraceSpec;

fn main() {
    // 1. A workload: 50 ports, 120 coflows, FB-like mixture (most coflows
    //    small, most bytes in a few wide ones).
    let trace = TraceSpec::fb_like(50, 120).seed(7).generate();
    println!(
        "workload: {} coflows, {} flows, {:.1} GB over {} ports",
        trace.coflows.len(),
        trace.flows.len(),
        trace.total_bytes() / 1e9,
        trace.num_ports
    );

    // 2. Run both schedulers on the identical trace.
    let cfg = SchedulerConfig::default();
    let aalo = Simulation::run(&trace, SchedulerKind::Aalo, &cfg);
    let philae = Simulation::run(&trace, SchedulerKind::Philae, &cfg);

    // 3. Per-coflow CCT speedups (Aalo CCT / Philae CCT).
    let row = SpeedupRow::from_ccts(&aalo.ccts, &philae.ccts);
    println!("philae vs aalo: {row}");

    // 4. The learning-cost asymmetry behind the speedup (Table 1): Philae
    //    hears only flow completions; Aalo also needs per-interval byte
    //    updates and recalculates rates every δ.
    println!(
        "coordinator economics: updates {} vs {}, rate calcs {} vs {}",
        philae.update_msgs, aalo.update_msgs, philae.rate_calcs, aalo.rate_calcs
    );

    // 5. Sanity: a clairvoyant oracle should be the best non-preemption-free
    //    policy; Philae should sit between Aalo and the oracle on average.
    let oracle = Simulation::run(&trace, SchedulerKind::Sebf, &cfg);
    println!(
        "avg CCT (s): oracle {:.3} <= philae {:.3} vs aalo {:.3}",
        oracle.avg_cct(),
        philae.avg_cct(),
        aalo.avg_cct()
    );
}
