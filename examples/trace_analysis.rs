//! Workload analysis: verifies the FB-like generator reproduces the trace
//! marginals the paper's results depend on (DESIGN.md §3) and prints the
//! distributions — coflow widths, bytes concentration, intra-coflow skew.
//!
//! ```bash
//! cargo run --release --example trace_analysis [trace-file]
//! ```

use philae::analysis::skew_distribution;
use philae::metrics::percentile;
use philae::trace::{Trace, TraceSpec};

fn main() -> anyhow::Result<()> {
    let trace = match std::env::args().nth(1) {
        Some(path) => Trace::load(path)?,
        None => TraceSpec::fb_like(150, 526).seed(42).generate(),
    };
    println!(
        "{} coflows, {} flows, {:.1} GB, {} ports, span {:.0}s",
        trace.coflows.len(),
        trace.flows.len(),
        trace.total_bytes() / 1e9,
        trace.num_ports,
        trace.makespan_lower_bound()
    );

    // Width distribution.
    let widths: Vec<f64> = trace.coflows.iter().map(|c| c.width() as f64).collect();
    println!("\nwidths: P10 {:.0}  P50 {:.0}  P90 {:.0}  max {:.0}",
        percentile(&widths, 10.0), percentile(&widths, 50.0),
        percentile(&widths, 90.0), percentile(&widths, 100.0));
    let narrow = trace.coflows.iter().filter(|c| c.width() <= 10).count();
    println!(
        "narrow (width ≤ 10): {:.0}% of coflows  (FB property: majority narrow)",
        100.0 * narrow as f64 / trace.coflows.len() as f64
    );

    // Bytes concentration: Lorenz-style.
    let oracles = trace.oracles();
    let mut sizes: Vec<f64> = oracles.iter().map(|o| o.total_bytes).collect();
    sizes.sort_by(f64::total_cmp);
    let total: f64 = sizes.iter().sum();
    let top10: f64 = sizes[sizes.len().saturating_sub(sizes.len() / 10)..].iter().sum();
    println!(
        "bytes held by largest 10% of coflows: {:.0}%  (FB property: bytes ≫ count)",
        100.0 * top10 / total
    );

    // Intra-coflow skew (§2.2's max/min metric).
    let sk = skew_distribution(&trace);
    println!(
        "\nintra-coflow skew (max/min): P50 {:.1}  P90 {:.1}  P99 {:.1}",
        percentile(&sk, 50.0),
        percentile(&sk, 90.0),
        percentile(&sk, 99.0)
    );

    // Coflow-size spread across coflows (drives SJF's benefit).
    println!(
        "coflow sizes: P10 {:.1} MB  P50 {:.1} MB  P90 {:.1} MB  max {:.1} GB",
        percentile(&sizes, 10.0) / 1e6,
        percentile(&sizes, 50.0) / 1e6,
        percentile(&sizes, 90.0) / 1e6,
        percentile(&sizes, 100.0) / 1e9
    );

    // Wide-only subset (Table 2 row 2).
    let wide = trace.wide_only();
    println!(
        "\nwide-only subset: {} coflows ({:.0}%), {:.0}% of bytes",
        wide.coflows.len(),
        100.0 * wide.coflows.len() as f64 / trace.coflows.len() as f64,
        100.0 * wide.total_bytes() / trace.total_bytes()
    );
    Ok(())
}
